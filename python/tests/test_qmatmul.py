"""Quantized-matmul Pallas kernel vs oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.qmatmul import qmatmul, BM, BK, BN
from compile.kernels.ref import qmatmul_ref, quantize_ref

RNG = np.random.default_rng(7)


def _rand(shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (128, 64, 128),
                                   (64, 128, 64), (192, 128, 64)])
@pytest.mark.parametrize("stochastic", [True, False])
def test_matches_ref(m, k, n, stochastic):
    a, b = _rand((m, k)), _rand((k, n))
    c = qmatmul(jnp.asarray(a), jnp.asarray(b), 4, 10, 4, 10, 3,
                stochastic=stochastic)
    cr = qmatmul_ref(a, b, 4, 10, 4, 10, 3, stochastic=stochastic)
    # Blocked accumulation reorders the k-sum: allclose, not equality.
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                               rtol=1e-5, atol=1e-4)


def test_single_kblock_exact():
    # One k-block means identical accumulation order: bit-exact.
    a, b = _rand((BM, BK)), _rand((BK, BN))
    c = qmatmul(jnp.asarray(a), jnp.asarray(b), 4, 10, 4, 10, 3)
    cr = qmatmul_ref(a, b, 4, 10, 4, 10, 3)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))


def test_operand_streams_decorrelated():
    """A and B tiles at the same flat index must not share noise."""
    x = _rand((64, 64))
    qa, _, _ = quantize_ref(x, 4, 10, 3)
    qb, _, _ = quantize_ref(x, 4, 10, 3 + 0x1234567)
    assert not np.array_equal(np.asarray(qa), np.asarray(qb))


def test_rejects_unaligned():
    with pytest.raises(AssertionError):
        qmatmul(jnp.zeros((65, 64)), jnp.zeros((64, 64)), 4, 8, 4, 8, 0)


@settings(max_examples=10, deadline=None)
@given(
    mi=st.integers(1, 3), ki=st.integers(1, 3), ni=st.integers(1, 3),
    il=st.integers(2, 8), fl=st.integers(4, 14),
    seed=st.integers(0, 2**30),
)
def test_matches_ref_hypothesis(mi, ki, ni, il, fl, seed):
    rng = np.random.default_rng(seed % 65537)
    a = (rng.standard_normal((mi * BM, ki * BK))).astype(np.float32)
    b = (rng.standard_normal((ki * BK, ni * BN))).astype(np.float32)
    c = qmatmul(jnp.asarray(a), jnp.asarray(b), il, fl, il, fl, seed)
    cr = qmatmul_ref(a, b, il, fl, il, fl, seed)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr),
                               rtol=1e-5, atol=1e-4)
