"""AOT layer tests: module specs are consistent, HLO text is emitted in the
xla_extension-0.5.1-safe dialect, and the manifest matches the lowered
signatures.  (Execution of the artifacts is covered by the Rust integration
tests; this guards the build path itself.)"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def modules():
    return aot.build_modules()


def test_all_expected_modules_present(modules):
    names = set(modules)
    for mname in ("mlp", "lenet"):
        for kind in ("train", "train_nearest", "train_float", "eval",
                     "eval_float"):
            assert f"{mname}_{kind}" in names
    assert "qmatmul_256" in names
    for n in (4096, 131072):
        assert f"quantize_sr_{n}" in names
    assert f"quantize_rn_4096" in names


def test_manifest_io_matches_example_args(modules):
    for name, (fn, eargs, meta) in modules.items():
        assert len(meta["inputs"]) == len(eargs), name
        for spec, arg in zip(meta["inputs"], eargs):
            assert tuple(spec["shape"]) == tuple(arg.shape), (name, spec)


def test_train_module_site_count(modules):
    for mname, spec in M.MODELS.items():
        meta = modules[f"{mname}_train"][2]
        nsites = len(meta["sites"])
        assert nsites == len(M.train_step_sites(spec))
        evec = [o for o in meta["outputs"] if o["name"] == "evec"][0]
        assert evec["shape"] == [nsites]
        classes = {s["class"] for s in meta["sites"]}
        assert classes == {"act", "grad", "weight"}


def test_float_modules_have_no_sites(modules):
    for mname in M.MODELS:
        assert modules[f"{mname}_train_float"][2]["sites"] == []


def test_lowering_emits_parseable_hlo_text(modules):
    # Small module end-to-end: lower + convert; HLO text must carry an
    # ENTRY computation and the right parameter count.
    fn, eargs, meta = modules["quantize_sr_4096"]
    lowered = jax.jit(fn).lower(*eargs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "HloModule" in text
    assert text.count("parameter(") >= len(meta["inputs"])


def test_train_signature_outputs(modules):
    fn, eargs, meta = modules["mlp_train"][0:3]
    out = jax.eval_shape(fn, *eargs)
    assert len(out) == len(meta["outputs"])
    for o_spec, o in zip(meta["outputs"], out):
        assert tuple(o_spec["shape"]) == tuple(o.shape), o_spec


def test_float_train_keeps_seed_and_prec_alive(modules):
    """StableHLO->XlaComputation prunes unused entry params; the float
    graph must anchor seed/prec so the artifact signature matches the
    manifest (regression for the 13-vs-11-buffers bug)."""
    fn, eargs, meta = modules["mlp_train_float"][0:3]
    lowered = jax.jit(fn).lower(*eargs)
    text = aot.to_hlo_text(lowered)
    entry = text[text.index("ENTRY"):]
    entry = entry[:entry.index("\n}")]
    n_params = entry.count("parameter(")
    assert n_params == len(meta["inputs"]), (
        f"entry has {n_params} params, manifest says {len(meta['inputs'])} "
        "(unused entry params were pruned)"
    )


def test_eval_modules_are_per_example(modules):
    """Eval artifacts emit f32[EVAL_BATCH] vectors so the host can mask
    wrapped tail entries exactly (non-multiple test sets)."""
    for mname in M.MODELS:
        for kind in ("eval", "eval_float"):
            fn, eargs, meta = modules[f"{mname}_{kind}"]
            outs = {o["name"]: o["shape"] for o in meta["outputs"]}
            assert outs["loss_vec"] == [aot.EVAL_BATCH]
            assert outs["correct_vec"] == [aot.EVAL_BATCH]
            shapes = jax.eval_shape(fn, *eargs)
            assert [tuple(s.shape) for s in shapes] == \
                [(aot.EVAL_BATCH,), (aot.EVAL_BATCH,)]


def test_train_modules_declare_donation(modules):
    """Train modules donate params+momenta (the first 2P args); eval
    modules must NOT donate — they re-use the resident buffers."""
    for mname in M.MODELS:
        for kind in ("train", "train_nearest", "train_float"):
            assert modules[f"{mname}_{kind}"][2]["donated"] is True
        for kind in ("eval", "eval_float"):
            assert not modules[f"{mname}_{kind}"][2].get("donated", False)


def test_params_npz_matches_manifest(tmp_path):
    for mname, spec in M.MODELS.items():
        params = M.init_params(spec, seed=0)
        path = tmp_path / f"{mname}.npz"
        np.savez(path, **{n: p for (n, _), p in zip(spec.params, params)})
        loaded = np.load(path)
        for (n, shape), p in zip(spec.params, params):
            assert loaded[n].shape == tuple(shape)
            np.testing.assert_array_equal(loaded[n], p)


def test_model_meta_shapes():
    meta = aot.model_meta()
    assert meta["lenet"]["input_shape"] == [28, 28, 1]
    assert meta["mlp"]["input_shape"] == [784]
    lenet_total = sum(
        int(np.prod(p["shape"])) for p in meta["lenet"]["params"]
    )
    assert lenet_total == 431_080  # the classic LeNet parameter count
