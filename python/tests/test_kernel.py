"""Pallas quantize kernel vs pure-jnp oracle — the core L1 correctness signal.

The kernel and the oracle are *independent* implementations of the spec in
DESIGN.md §4; quantized values must agree **bit-for-bit**, stats to float
tolerance (summation order differs: per-block partials vs one big mean).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.quantize import quantize, BLOCK, exp2i, hash_u32, uniform01
from compile.kernels.ref import quantize_ref

RNG = np.random.default_rng(1234)


def _rand(shape, scale=4.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


def _check(x, il, fl, seed, stochastic):
    q, e, r = quantize(jnp.asarray(x), il, fl, seed, stochastic=stochastic)
    qr, er, rr = quantize_ref(x, il, fl, seed, stochastic=stochastic)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(float(e), float(er), rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(float(r), float(rr), rtol=1e-5, atol=1e-7)
    return np.asarray(q), float(e), float(r)


# ---------------------------------------------------------------------------
# Kernel == oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1,), (7,), (64,), (1000,),
                                   (64, 100), (28, 28, 1), (2, 3, 4, 5)])
@pytest.mark.parametrize("stochastic", [True, False])
def test_matches_ref_shapes(shape, stochastic):
    _check(_rand(shape), 4, 8, 42, stochastic)


@pytest.mark.parametrize("il,fl", [(1, 0), (1, 24), (8, 8), (16, 14),
                                   (4, 9), (2, 22), (30, 0)])
def test_matches_ref_formats(il, fl):
    _check(_rand((513,)), il, fl, 7, True)


def test_matches_ref_multiblock():
    # > BLOCK elements exercises the grid + per-block stat partials.
    _check(_rand((BLOCK + 1717,)), 5, 10, 3, True)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 3000),
    il=st.integers(1, 24),
    fl=st.integers(0, 24),
    seed=st.integers(0, 2**31 - 1),
    stochastic=st.booleans(),
    scale=st.floats(1e-3, 1e3),
)
def test_matches_ref_hypothesis(n, il, fl, seed, stochastic, scale):
    rng = np.random.default_rng(seed % 100003)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    _check(x, il, fl, seed, stochastic)


# ---------------------------------------------------------------------------
# Quantizer semantics (oracle-independent invariants)
# ---------------------------------------------------------------------------

def test_values_on_grid():
    q, _, _ = quantize(jnp.asarray(_rand((4096,))), 4, 6, 9)
    scaled = np.asarray(q) * 64.0
    np.testing.assert_array_equal(scaled, np.round(scaled))


def test_range_clipped():
    x = _rand((4096,), scale=100.0)
    q, _, r = quantize(jnp.asarray(x), 4, 6, 9)
    q = np.asarray(q)
    assert q.max() <= 8.0 - 2.0**-6 + 1e-9
    assert q.min() >= -8.0 - 1e-9
    assert float(r) > 0  # scale=100 guarantees saturation


def test_idempotent_nearest():
    x = jnp.asarray(_rand((2048,)))
    q1, _, _ = quantize(x, 6, 8, 1, stochastic=False)
    q2, _, _ = quantize(q1, 6, 8, 2, stochastic=False)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


def test_idempotent_stochastic():
    # On-grid values have zero fractional part: u < 1 never rounds them away.
    x = jnp.asarray(_rand((2048,)))
    q1, _, _ = quantize(x, 6, 8, 1, stochastic=True)
    q2, e2, _ = quantize(q1, 6, 8, 99, stochastic=True)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    assert float(e2) == 0.0


def test_stochastic_unbiased():
    """E[Q(x)] == x within CI — the whole point of Eq. 2 over Eq. 1."""
    x = jnp.full((8,), 0.3, jnp.float32)   # 0.3 is off-grid for FL=4
    acc = np.zeros(8, np.float64)
    n = 4000
    for s in range(n):
        q, _, _ = quantize(x, 4, 4, s)
        acc += np.asarray(q, np.float64)
    mean = acc / n
    # step=1/16; SE of mean ~ step/sqrt(n) ~ 0.001
    np.testing.assert_allclose(mean, 0.3, atol=5e-3)


def test_nearest_biased_on_same_input():
    """Round-to-nearest maps 0.3 -> 0.3125 every time: bias = 0.0125."""
    x = jnp.full((8,), 0.3, jnp.float32)
    q, _, _ = quantize(x, 4, 4, 0, stochastic=False)
    np.testing.assert_allclose(np.asarray(q), 0.3125, atol=1e-7)


def test_error_metric_decreases_with_fl():
    x = jnp.asarray(_rand((8192,), scale=0.5))
    es = [float(quantize(x, 4, fl, 5)[1]) for fl in (2, 6, 10, 14)]
    assert es == sorted(es, reverse=True), es


def test_overflow_rate_decreases_with_il():
    x = jnp.asarray(_rand((8192,), scale=8.0))
    rs = [float(quantize(x, il, 8, 5)[2]) for il in (1, 3, 5, 8)]
    assert rs == sorted(rs, reverse=True), rs
    assert rs[0] > 0.5 and rs[-1] < 0.05


def test_zero_input_zero_stats():
    q, e, r = quantize(jnp.zeros((1024,)), 4, 8, 11)
    assert float(e) == 0.0 and float(r) == 0.0
    np.testing.assert_array_equal(np.asarray(q), 0.0)


def test_seed_changes_rounding():
    x = jnp.full((4096,), 0.3, jnp.float32)
    q1, _, _ = quantize(x, 4, 4, 1)
    q2, _, _ = quantize(x, 4, 4, 2)
    assert not np.array_equal(np.asarray(q1), np.asarray(q2))


def test_il_fl_clamped():
    # Out-of-range IL/FL must not produce NaN/inf.
    x = jnp.asarray(_rand((128,)))
    q, e, r = quantize(x, 99, 99, 1)
    assert np.isfinite(np.asarray(q)).all()
    q, e, r = quantize(x, -5, -5, 1)
    assert np.isfinite(np.asarray(q)).all()


# ---------------------------------------------------------------------------
# Helper primitives (these are the spec the Rust mirror implements)
# ---------------------------------------------------------------------------

def test_exp2i_exact():
    for e in range(-30, 31):
        assert float(exp2i(jnp.int32(e))) == 2.0 ** e


def test_hash_reference_vectors():
    """Pinned vectors — rust/src/fixedpoint/quantize.rs asserts the same."""
    idx = jnp.asarray([0, 1, 2, 12345, 0xFFFFFFFF], jnp.uint32)
    got = [int(v) for v in hash_u32(idx, jnp.uint32(42))]
    def mix(i, s):
        x = (i * 0x9E3779B9 + s) & 0xFFFFFFFF
        x ^= x >> 16; x = (x * 0x85EBCA6B) & 0xFFFFFFFF
        x ^= x >> 13; x = (x * 0xC2B2AE35) & 0xFFFFFFFF
        return x ^ (x >> 16)
    want = [mix(i, 42) for i in [0, 1, 2, 12345, 0xFFFFFFFF]]
    assert got == want


def test_uniform_range():
    u = np.asarray(uniform01(jnp.arange(10000, dtype=jnp.uint32),
                             jnp.uint32(7)))
    assert (u >= 0).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 0.02
