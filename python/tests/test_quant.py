"""quant.py (L2 plumbing) tests: site bookkeeping, seed disjointness,
stat ordering — the contract the manifest + Rust controller rely on."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.quant import QuantCtx, SITE_STRIDE, BWD_OFFSET, make_qfun

PREC = jnp.asarray([4, 8, 4, 8, 4, 12], jnp.float32)


def _x(n=256, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n).astype(np.float32))


def test_sites_recorded_in_call_order():
    ctx = QuantCtx(PREC, 1.0)
    ctx.act(_x(), "a0")
    ctx.grad(_x(), "g0")
    ctx.weight(_x(), "w0")
    ctx.act(_x(), "a1")
    assert ctx.site_list() == [("a0", "act"), ("g0", "grad"),
                               ("w0", "weight"), ("a1", "act")]
    e, r = ctx.stats()
    assert e.shape == (4,) and r.shape == (4,)


def test_disabled_ctx_is_identity():
    ctx = QuantCtx(PREC, 1.0, enabled=False)
    x = _x()
    assert ctx.act(x, "a") is x
    assert ctx.grad(x, "g") is x
    assert ctx.weight(x, "w") is x
    assert ctx.site_list() == []
    e, r = ctx.stats()  # length-1 zero vectors for the float artifact
    assert e.shape == (1,) and float(e[0]) == 0.0


def test_site_seeds_disjoint():
    """Two sites quantizing the same tensor must use different noise."""
    ctx = QuantCtx(PREC, 1.0)
    x = _x()
    q1 = ctx.act(x, "s1")
    q2 = ctx.act(x, "s2")
    assert not np.array_equal(np.asarray(q1), np.asarray(q2))


def test_start_offset_continues_numbering():
    """ctx(start=k) site j must equal ctx(start=0) site k+j (seed contract
    between the fwd trace and the update-time context in the train step)."""
    x = _x()
    a = QuantCtx(PREC, 1.0)
    a.act(x, "0")
    a.act(x, "1")
    q_site1 = a.act(x, "2")          # global site index 2
    b = QuantCtx(PREC, 1.0, start=2)
    q_b = b.act(x, "2b")             # also global site index 2
    np.testing.assert_array_equal(np.asarray(q_site1), np.asarray(q_b))


def test_class_prec_selection():
    """act uses <ILa,FLa>; weight uses <ILw,FLw>; grad uses <ILg,FLg>."""
    prec = jnp.asarray([2, 2, 4, 8, 6, 14], jnp.float32)
    ctx = QuantCtx(prec, 1.0, stochastic=False)
    x = jnp.full((64,), 1.3, jnp.float32)
    w = ctx.weight(x, "w")   # step 0.25 -> 1.25
    a = ctx.act(x, "a")      # step 1/256
    g = ctx.grad(x, "g")     # step 1/16384
    np.testing.assert_allclose(np.asarray(w), 1.25)
    np.testing.assert_allclose(np.asarray(a), 1.30078125)
    assert abs(float(g[0]) - 1.3) < 2**-14


def test_weight_site_clips_to_weight_range():
    prec = jnp.asarray([2, 8, 8, 8, 8, 8], jnp.float32)  # ILw=2 -> [-2,2)
    ctx = QuantCtx(prec, 1.0)
    w = ctx.weight(jnp.full((16,), 7.0, jnp.float32), "w")
    assert float(np.max(np.asarray(w))) <= 2.0
    _, r = ctx.stats()
    assert float(r[0]) == 1.0  # every element overflowed


def test_bwd_seed_differs_from_fwd():
    """The STE backward pass must not reuse the forward noise stream."""
    qfun = make_qfun(True)
    x = _x(128, 3)

    def f(x):
        q, _, _ = qfun(x, jnp.float32(4), jnp.float32(8), jnp.float32(4),
                       jnp.float32(8), jnp.float32(5.0))
        return jnp.sum(q)

    g = jax.grad(f)(x)  # cotangent of ones quantized at <4,8>
    # ones are exactly representable: gradient == 1 everywhere regardless of
    # noise; instead check the constant is what decorrelates streams
    assert BWD_OFFSET != 0 and BWD_OFFSET % SITE_STRIDE != 0
    np.testing.assert_allclose(np.asarray(g), 1.0)


def test_stats_are_concrete_under_jit():
    @jax.jit
    def step(x, prec, seed):
        ctx = QuantCtx(prec, seed)
        q = ctx.act(x, "a")
        e, r = ctx.stats()
        return q, e, r

    q, e, r = step(_x(), PREC, jnp.float32(2.0))
    assert np.isfinite(np.asarray(e)).all()
    assert 0.0 <= float(r[0]) <= 1.0
