"""L2 model/step tests: shapes, semantics, convergence smoke, STE behaviour."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile.quant import QuantCtx, make_qfun

RNG = np.random.default_rng(99)
PREC_WIDE = jnp.asarray([6, 18, 6, 18, 6, 20], jnp.float32)


def _setup(spec, batch=32):
    params = [jnp.asarray(p) for p in M.init_params(spec)]
    mom = [jnp.zeros_like(p) for p in params]
    x = jnp.asarray(RNG.standard_normal(
        (batch,) + tuple(spec.input_shape)).astype(np.float32))
    y = jnp.asarray(RNG.integers(0, 10, batch).astype(np.int32))
    return params, mom, x, y


# ---------------------------------------------------------------------------
# Shapes / plumbing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mname", ["mlp", "lenet", "transformer"])
@pytest.mark.parametrize("quantized", [True, False])
def test_train_step_shapes(mname, quantized):
    spec = M.MODELS[mname]
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=quantized))
    params, mom, x, y = _setup(spec, batch=8)
    out = step(*params, *mom, x, y, jnp.float32(0.01), jnp.float32(1.0),
               PREC_WIDE)
    assert len(out) == 2 * P + 4
    for p, o in zip(params + mom, out[:2 * P]):
        assert p.shape == o.shape
    nsites = len(M.train_step_sites(spec)) if quantized else 1
    assert out[2 * P + 2].shape == (nsites,)
    assert out[2 * P + 3].shape == (nsites,)


@pytest.mark.parametrize("mname", ["mlp", "lenet", "transformer"])
def test_site_list_matches_stats_length(mname):
    spec = M.MODELS[mname]
    sites = M.train_step_sites(spec)
    assert len(sites) == {"mlp": 3 + 8, "lenet": 5 + 16,
                          "transformer": 7 + 58}[mname]
    classes = [c for _, c in sites]
    assert classes.count("act") == {"mlp": 3, "lenet": 5,
                                    "transformer": 7}[mname]
    assert classes.count("grad") == len(spec.params)
    assert classes.count("weight") == len(spec.params)


@pytest.mark.parametrize("mname", ["mlp", "lenet"])
def test_eval_step(mname):
    spec = M.MODELS[mname]
    evalf = jax.jit(M.make_eval_step(spec, quantized=True))
    params, _, x, y = _setup(spec, batch=16)
    loss_vec, correct_vec = evalf(*params, x, y, PREC_WIDE)
    # per-example outputs: the host masks wrapped tail entries exactly
    assert loss_vec.shape == (16,)
    assert correct_vec.shape == (16,)
    cv = np.asarray(correct_vec)
    assert set(np.unique(cv)) <= {0.0, 1.0}
    assert float(loss_vec.mean()) > 1.0  # untrained ~ ln(10)


def test_init_deterministic():
    a = M.init_params(M.MLP, seed=0)
    b = M.init_params(M.MLP, seed=0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = M.init_params(M.MLP, seed=1)
    assert not np.array_equal(a[0], c[0])


def test_biases_zero_init():
    for spec in (M.MLP, M.LENET):
        for (name, _), p in zip(spec.params, M.init_params(spec)):
            if M._is_bias(name):
                assert np.all(p == 0), name


def test_transformer_init_conventions():
    spec = M.TRANSFORMER
    for (name, _), p in zip(spec.params, M.init_params(spec)):
        if name.startswith("g"):
            assert np.all(p == 1.0), name      # layernorm gains
        elif name == "pos":
            assert 0 < np.abs(p).max() < 0.2   # small positional init
        elif M._is_bias(name):
            assert np.all(p == 0), name


def test_transformer_learns_on_toy():
    spec = M.TRANSFORMER
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=True))
    params = [jnp.asarray(p) for p in M.init_params(spec)]
    mom = [jnp.zeros_like(p) for p in params]
    x, y = _toy_problem(spec, n=64)
    state = list(params) + list(mom)
    loss0 = None
    for i in range(25):
        out = step(*state, x, y, jnp.float32(0.02), jnp.float32(float(i)),
                   PREC_WIDE)
        state = list(out[:2 * P])
        if loss0 is None:
            loss0 = float(out[2 * P])
    assert float(out[2 * P]) < 0.6 * loss0, (loss0, float(out[2 * P]))


# ---------------------------------------------------------------------------
# Semantics
# ---------------------------------------------------------------------------

def test_weights_on_grid_after_step():
    """Stored weights must be on the <ILw, FLw> grid (fixed-point storage)."""
    spec = M.MLP
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=True))
    params, mom, x, y = _setup(spec)
    prec = jnp.asarray([4, 8, 6, 12, 6, 16], jnp.float32)
    out = step(*params, *mom, x, y, jnp.float32(0.05), jnp.float32(1.0), prec)
    for w in out[:P]:
        scaled = np.asarray(w) * 2.0**8
        np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-3)


def test_float_step_is_pure_float():
    """Float baseline must not quantize: step == hand-computed SGD update."""
    spec = M.MLP
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=False))
    params, mom, x, y = _setup(spec)
    lr = jnp.float32(0.01)
    out = step(*params, *mom, x, y, lr, jnp.float32(1.0), PREC_WIDE)

    def loss_fn(ps):
        ctx = QuantCtx(PREC_WIDE, 0.0, enabled=False)
        logits = spec.forward(ps, x, ctx)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))

    grads = jax.grad(loss_fn)(params)
    for w, g, w_new in zip(params, grads, out[:P]):
        v = M.MU * 0.0 + lr * (g + M.WD * w)
        np.testing.assert_allclose(np.asarray(w - v), np.asarray(w_new),
                                   rtol=1e-6, atol=1e-7)


def test_determinism_same_seed():
    spec = M.MLP
    step = jax.jit(M.make_train_step(spec, quantized=True))
    params, mom, x, y = _setup(spec)
    args = (*params, *mom, x, y, jnp.float32(0.01), jnp.float32(5.0),
            PREC_WIDE)
    o1, o2 = step(*args), step(*args)
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_different_seed_different_result():
    spec = M.MLP
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=True))
    params, mom, x, y = _setup(spec)
    prec = jnp.asarray([4, 6, 4, 6, 4, 8], jnp.float32)  # coarse => visible
    o1 = step(*params, *mom, x, y, jnp.float32(0.05), jnp.float32(1.0), prec)
    o2 = step(*params, *mom, x, y, jnp.float32(0.05), jnp.float32(2.0), prec)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(o1[:P], o2[:P]))


def test_coarse_weight_prec_raises_weight_error():
    spec = M.MLP
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=True))
    params, mom, x, y = _setup(spec)
    sites = M.train_step_sites(spec)
    widx = [i for i, (_, c) in enumerate(sites) if c == "weight"]
    es = {}
    for flw in (4, 12):
        prec = jnp.asarray([4, flw, 6, 12, 6, 16], jnp.float32)
        out = step(*params, *mom, x, y, jnp.float32(0.01), jnp.float32(1.0),
                   prec)
        evec = np.asarray(out[2 * P + 2])
        es[flw] = evec[widx].mean()
    assert es[4] > es[12]


def test_saturating_act_prec_raises_overflow():
    spec = M.MLP
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=True))
    params, mom, x, y = _setup(spec)
    sites = M.train_step_sites(spec)
    aidx = [i for i, (_, c) in enumerate(sites) if c == "act"]
    prec = jnp.asarray([6, 12, 1, 12, 6, 16], jnp.float32)  # ILa=1 saturates
    out = step(*params, *mom, x, y, jnp.float32(0.01), jnp.float32(1.0), prec)
    rvec = np.asarray(out[2 * P + 3])
    assert rvec[aidx].max() > 0.01


# ---------------------------------------------------------------------------
# STE
# ---------------------------------------------------------------------------

def test_ste_passes_gradient():
    qfun = make_qfun(True)

    def f(x):
        q, _, _ = qfun(x, jnp.float32(6), jnp.float32(12), jnp.float32(6),
                       jnp.float32(20), jnp.float32(1.0))
        return jnp.sum(q * q)

    x = jnp.asarray(RNG.standard_normal(64).astype(np.float32))
    g = jax.grad(f)(x)
    # STE: d/dx sum(Q(x)^2) ~ 2 Q(x); gradient itself then quantized at FL=20.
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(x),
                               rtol=0.1, atol=0.05)


def test_ste_gradient_is_quantized():
    qfun = make_qfun(True)
    flg = 8

    def f(x):
        q, _, _ = qfun(x, jnp.float32(6), jnp.float32(18), jnp.float32(6),
                       jnp.float32(flg), jnp.float32(1.0))
        return jnp.sum(jnp.sin(q))

    x = jnp.asarray(RNG.standard_normal(64).astype(np.float32))
    g = np.asarray(jax.grad(f)(x))
    scaled = g * 2.0**flg
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-3)


# ---------------------------------------------------------------------------
# Convergence smoke
# ---------------------------------------------------------------------------

def _toy_problem(spec, n=256):
    """Linearly-separable-ish synthetic task the model must fit quickly."""
    rng = np.random.default_rng(5)
    protos = rng.standard_normal((10,) + tuple(spec.input_shape)) * 1.5
    y = rng.integers(0, 10, n)
    x = protos[y] + 0.3 * rng.standard_normal((n,) + tuple(spec.input_shape))
    return (jnp.asarray(x.astype(np.float32)),
            jnp.asarray(y.astype(np.int32)))


@pytest.mark.parametrize("quantized", [True, False])
def test_mlp_converges_on_toy(quantized):
    spec = M.MLP
    P = len(spec.params)
    step = jax.jit(M.make_train_step(spec, quantized=quantized))
    params = [jnp.asarray(p) for p in M.init_params(spec)]
    mom = [jnp.zeros_like(p) for p in params]
    x, y = _toy_problem(spec)
    state = list(params) + list(mom)
    loss0 = None
    for i in range(60):
        out = step(*state, x, y, jnp.float32(0.05), jnp.float32(float(i)),
                   PREC_WIDE)
        state = list(out[:2 * P])
        if loss0 is None:
            loss0 = float(out[2 * P])
    assert float(out[2 * P]) < 0.3 * loss0
    assert float(out[2 * P + 1]) > 0.9
