"""Pure-jnp correctness oracle for the L1 kernels.

Deliberately re-implements the quantizer spec *independently* of
``quantize.py`` (no shared helpers): the tests assert the Pallas kernel and
this oracle agree bit-for-bit, so any transcription slip in either shows up.

Spec (DESIGN.md §4):
  format <IL, FL>, step eps = 2^-FL, range [-2^(IL-1), 2^(IL-1) - eps]
  stochastic:  q = clip(floor(x * 2^FL + u) * 2^-FL)   u = hash-uniform[0,1)
  nearest:     u = 0.5
  R = mean(x outside range),  E = sum|q - x| / (sum|x| + 1e-8)
  hash = murmur3 finalizer over (flat_index * 0x9E3779B9 + seed), top 24
  bits -> uniform.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _pow2(e):
    """2**e for integer e, via the f32 exponent field (exact)."""
    bits = (jnp.asarray(e, jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits.astype(jnp.int32), jnp.float32)


def _uniform(n, seed):
    idx = jnp.arange(n, dtype=jnp.uint32)
    x = idx * jnp.uint32(0x9E3779B9) + jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> jnp.uint32(16))
    return (x >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def quantize_ref(x, il, fl, seed, *, stochastic=True):
    """Oracle quantizer. Same contract as ``quantize.quantize``."""
    x = jnp.asarray(x, jnp.float32)
    il = jnp.clip(jnp.asarray(il, jnp.int32), 1, 30)
    fl = jnp.clip(jnp.asarray(fl, jnp.int32), 0, 30)
    shape, n = x.shape, x.size
    flat = x.reshape(-1)

    if stochastic:
        u = _uniform(n, seed)
    else:
        u = jnp.full((n,), 0.5, jnp.float32)

    s = _pow2(fl)
    inv_s = _pow2(-fl)
    hi = _pow2(il - 1) - inv_s
    lo = -_pow2(il - 1)
    xs = flat * s
    fl_part = jnp.floor(xs)
    r = xs - fl_part
    up = (r >= u) if not stochastic else (r > u)
    q = jnp.clip((fl_part + up.astype(jnp.float32)) * inv_s, lo, hi)
    ovf = jnp.logical_or(flat < lo, flat > hi)
    # E = ratio of means: sum|q-x| / (sum|x| + eps) — see quantize.py.
    e = jnp.sum(jnp.abs(q - flat)) / (jnp.sum(jnp.abs(flat)) + jnp.float32(1e-8))
    return q.reshape(shape), e, jnp.mean(ovf.astype(jnp.float32))


def qmatmul_ref(a, b, il_a, fl_a, il_w, fl_w, seed, *, stochastic=True):
    """Oracle for the quantized matmul kernel: Q(a) @ Q(b), f32 accumulate.

    The two operands draw noise from decorrelated seed streams (seed and
    seed + 0x1234567, matching the kernel).
    """
    qa, _, _ = quantize_ref(a, il_a, fl_a, seed, stochastic=stochastic)
    qb, _, _ = quantize_ref(
        b, il_w, fl_w, jnp.asarray(seed, jnp.int32) + 0x1234567, stochastic=stochastic
    )
    return jnp.dot(qa, qb, preferred_element_type=jnp.float32)
