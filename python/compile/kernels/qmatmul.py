"""L1: Pallas quantized matmul — the flexible-MAC analogue on TPU.

The paper's target hardware (Na & Mukhopadhyay's flexible
multiplier-accumulator) consumes *already-quantized* fixed-point operands
and accumulates in a wide register.  On TPU the same insight maps to:
quantize operand tiles on the way from HBM into VMEM (VPU elementwise work),
then feed the MXU with the quantized tiles, accumulating in f32.  This
kernel implements that pipeline:

    C[i,j] = sum_k  Q_a(A[i,k]-tile) @ Q_w(B[k,j]-tile)     (f32 accumulate)

Tiles are quantized with the same counter-hash stochastic rounding as
``quantize.py``, indexed by each element's *global* flat position so a tile
quantizes identically regardless of which grid step touches it.

Grid iteration order is (i, j, k) with k innermost; the output tile is
zeroed at k == 0 and accumulated across k — the standard Pallas matmul
schedule, expressing with ``BlockSpec`` what a CUDA kernel would express
with threadblock tiling.  ``interpret=True`` for CPU-PJRT executability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import uniform01, _quantize_block

# Seed offset decorrelating the weight stream from the activation stream.
WSEED_OFFSET = 0x1234567

BM, BK, BN = 64, 64, 64


def _flat_idx(row0, col0, rows, cols, row_stride):
    """Global flat indices (u32) of a (rows, cols) tile at (row0, col0)."""
    r = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 0) + row0
    c = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1) + col0
    return (r * row_stride + c).astype(jnp.uint32)


def _kernel(params_ref, a_ref, b_ref, o_ref, *, k_dim, n_dim, stochastic):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    seed, il_a, fl_a, il_w, fl_w = (params_ref[t] for t in range(5))

    a = a_ref[...]
    b = b_ref[...]

    ia = _flat_idx(i * BM, k * BK, BM, BK, k_dim)
    iw = _flat_idx(k * BK, j * BN, BK, BN, n_dim)
    if stochastic:
        ua = uniform01(ia, seed)
        uw = uniform01(iw, seed + WSEED_OFFSET)
    else:
        ua = jnp.full((BM, BK), 0.5, jnp.float32)
        uw = jnp.full((BK, BN), 0.5, jnp.float32)

    qa, _, _, _ = _quantize_block(a, ua, il_a, fl_a, nearest=not stochastic)
    qb, _, _, _ = _quantize_block(b, uw, il_w, fl_w, nearest=not stochastic)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(qa, qb, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("stochastic",))
def qmatmul(a, b, il_a, fl_a, il_w, fl_w, seed, *, stochastic=True):
    """C = Q_a(a) @ Q_w(b) with f32 accumulation.

    Shapes must tile evenly by (64, 64, 64); the model layer sizes are
    chosen to satisfy this (the general train step quantizes via
    ``quantize.quantize`` + XLA dot instead).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % BM == 0 and k % BK == 0 and n % BN == 0, (a.shape, b.shape)
    params = jnp.stack(
        [jnp.asarray(v, jnp.int32) for v in (seed, il_a, fl_a, il_w, fl_w)]
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, k_dim=k, n_dim=n, stochastic=stochastic
        ),
        grid=(m // BM, n // BN, k // BK),
        in_specs=[
            pl.BlockSpec((5,), lambda i, j, k: (0,)),
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(params, a.astype(jnp.float32), b.astype(jnp.float32))
