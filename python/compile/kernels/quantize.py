"""L1: Pallas stochastic fixed-point quantization kernel.

The paper emulates a dynamic fixed-point format ``<IL, FL>`` (integer length,
fractional length; IL includes the sign bit) by rounding float tensors.  This
kernel is the compute hot-spot of that emulation: elementwise

    q = clip( floor(x * 2^FL + u) * 2^-FL ,  -2^(IL-1),  2^(IL-1) - 2^-FL )

with ``u ~ U[0,1)`` (paper Eq. 2, stochastic rounding) or ``u = 0.5``
(paper Eq. 1, round-to-nearest), plus the two feedback statistics the
dynamic-precision-scaling controller consumes:

    R = mean( x outside representable range )      -> drives IL
    E = sum|q - x| / (sum|x| + 1e-8)               -> drives FL

Design notes
------------
* ``IL``/``FL``/``seed`` are **runtime inputs** (traced scalars), so the AOT
  artifact can be driven at a new precision every iteration without
  recompilation.
* Randomness is a counter-based integer hash (murmur3-style avalanche over
  ``flat_index * GOLDEN + seed``), not threefry: stateless, lowers to plain
  HLO integer ops, and is mirrored bit-exactly by
  ``rust/src/fixedpoint/quantize.rs`` so the Rust coordinator can verify the
  HLO artifact element-for-element.
* ``2^e`` is built by writing the exponent field of an f32 directly
  (``(e+127) << 23`` bitcast), never ``exp(e*ln2)``: exact for all integer
  ``e`` in range, and trivially mirrored in Rust.
* The kernel runs under ``interpret=True`` — the CPU PJRT plugin cannot
  execute Mosaic custom-calls.  Block structure is still TPU-shaped: a flat
  block of ``BLOCK`` elements is one ``(BLOCK/128, 128)`` VMEM tile
  (512 KiB at the default), with per-block partial stat sums so the stats
  reduction is two tiny reductions instead of a full-size second pass.

Float-emulation caveat (shared with the paper's Caffe emulation): once
``IL + FL`` exceeds the 24-bit f32 mantissa, the grid arithmetic and the
upper clip bound are themselves rounded.  The dynamics this paper reports
live at <= 20 total bits, where the emulation is exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat elements per grid step.  128 lanes x 512 sublanes = 256 KiB of f32 in,
# 256 KiB out: comfortably double-bufferable in 16 MiB of VMEM.
BLOCK = 65536

GOLDEN = 0x9E3779B9
MIX1 = 0x85EBCA6B
MIX2 = 0xC2B2AE35
EPS = 1e-8

# Hard bounds on IL/FL accepted by the kernel (controller clamps harder).
IL_MIN, IL_MAX = 1, 30
FL_MIN, FL_MAX = 0, 30


def exp2i(e):
    """Exact 2**e for integer-valued i32 ``e`` in [-126, 127].

    Builds the f32 exponent field directly; bit-exact and branch-free, and
    mirrored by ``fixedpoint::exp2i`` on the Rust side.
    """
    e = e.astype(jnp.int32) if hasattr(e, "astype") else jnp.int32(e)
    bits = (e + jnp.int32(127)) << jnp.int32(23)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def hash_u32(idx, seed):
    """Counter-based avalanche hash: u32 x u32 -> u32 (murmur3 finalizer)."""
    x = idx.astype(jnp.uint32) * jnp.uint32(GOLDEN) + seed.astype(jnp.uint32)
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(MIX1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(MIX2)
    x = x ^ (x >> jnp.uint32(16))
    return x


def uniform01(idx, seed):
    """U[0,1) with a 24-bit mantissa: every value exactly representable."""
    h = hash_u32(idx, seed) >> jnp.uint32(8)
    return h.astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def _quantize_block(x, u, il, fl, *, nearest=False):
    """Shared elementwise math: returns (q, err, ovf) for one block.

    Rounding is the residual-comparison form, not ``floor(x*s + u)``: the
    naive add can spill to the next integer in f32 when ``x*s`` is large and
    ``u`` is close to 1, breaking idempotence/unbiasedness.  The residual
    ``r = x*s - floor(x*s)`` is *exact* in f32 (Sterbenz), so

        stochastic: round up  iff  r > u      (P = r, exactly Eq. 2)
        nearest:    round up  iff  r >= 0.5   (half-up, Eq. 1)
    """
    s = exp2i(fl)
    inv_s = exp2i(-fl)
    hi = exp2i(il - 1) - inv_s   # largest representable value
    lo = -exp2i(il - 1)          # most negative representable value
    xs = x * s
    f = jnp.floor(xs)
    r = xs - f                   # exact: f/2 <= xs <= 2f (or f == 0)
    up = (r >= u) if nearest else (r > u)
    y = (f + up.astype(jnp.float32)) * inv_s
    q = jnp.clip(y, lo, hi)
    ovf = jnp.logical_or(x < lo, x > hi).astype(jnp.float32)
    # E is a ratio of means: sum|q-x| / sum|x| (computed by the wrapper).
    # Per-element |q-x|/|x| would be dominated by near-zero entries (a
    # rounded-to-zero 1e-6 weight scores relative error ~1), which starves
    # the controller of signal; the ratio-of-means reading of the paper's
    # "average quantization error percentage" is scale-free and stable.
    err = jnp.abs(q - x)
    mag = jnp.abs(x)
    return q, err, ovf, mag


def _kernel(params_ref, x_ref, q_ref, esum_ref, rsum_ref, xsum_ref, *,
            stochastic):
    """One grid step: quantize BLOCK elements, emit partial stat sums.

    params_ref: i32[3] = [seed, il, fl] (runtime scalars, replicated per
    block).  esum/rsum/xsum are (1,) per-block partials; the wrapper
    reduces them.
    """
    i = pl.program_id(0)
    seed = params_ref[0]
    il = params_ref[1]
    fl = params_ref[2]
    x = x_ref[...]
    idx = (i * BLOCK + jax.lax.iota(jnp.int32, BLOCK)).astype(jnp.uint32)
    if stochastic:
        u = uniform01(idx, seed)
    else:
        u = jnp.full((BLOCK,), 0.5, jnp.float32)
    q, err, ovf, mag = _quantize_block(x, u, il, fl, nearest=not stochastic)
    q_ref[...] = q
    esum_ref[0] = jnp.sum(err)
    rsum_ref[0] = jnp.sum(ovf)
    xsum_ref[0] = jnp.sum(mag)


@functools.partial(jax.jit, static_argnames=("stochastic",))
def quantize(x, il, fl, seed, *, stochastic=True):
    """Quantize ``x`` to fixed point ``<il, fl>``; returns ``(q, e, r)``.

    Args:
      x: any-shape f32 tensor.
      il, fl: i32 scalars (traced — may change every call without recompile).
      seed: i32/u32 scalar; vary per call for fresh stochastic-rounding noise.
      stochastic: Eq. 2 (True) vs Eq. 1 round-to-nearest (False). Static.

    Returns:
      q: quantized tensor, same shape/dtype as ``x``.
      e: scalar mean relative quantization error (the paper's ``E``).
      r: scalar overflow rate (the paper's ``R``).
    """
    x = x.astype(jnp.float32)
    il = jnp.clip(jnp.asarray(il, jnp.int32), IL_MIN, IL_MAX)
    fl = jnp.clip(jnp.asarray(fl, jnp.int32), FL_MIN, FL_MAX)
    seed = jnp.asarray(seed, jnp.int32)

    shape = x.shape
    n = x.size
    flat = x.reshape(-1)
    nb = max(1, -(-n // BLOCK))
    pad = nb * BLOCK - n
    if pad:
        # Zero padding is stat-neutral: q(0)=0, err(0)=0, ovf(0)=0.
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    tiles = flat.reshape(nb, BLOCK)
    params = jnp.stack([seed, il, fl]).astype(jnp.int32)

    q, esum, rsum, xsum = pl.pallas_call(
        functools.partial(_kernel, stochastic=stochastic),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, BLOCK), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=True,
    )(params, tiles)

    q = q.reshape(-1)[:n].reshape(shape)
    e = jnp.sum(esum) / (jnp.sum(xsum) + jnp.float32(EPS))
    return q, e, jnp.sum(rsum) * jnp.float32(1.0 / n)
