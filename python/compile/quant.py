"""L2 quantization plumbing: STE autodiff wrappers + per-site stat collection.

The paper's Caffe "round layers" quantize tensors on the forward pass and
quantize the gradients flowing through them on the backward pass.  In JAX
that is a ``custom_vjp``:

  forward:   y = Q_<ILa,FLa>(x)          (+ records E, R for the site)
  backward:  dx = Q_<ILg,FLg>(dy)        (straight-through + grad rounding)

Rounding is piecewise-constant so the true derivative is zero a.e.; the
straight-through estimator passes the cotangent through the rounding and
then rounds *it* — exactly what fixed-point backward arithmetic does.

``QuantCtx`` assigns every quantization site a stable index (the manifest
records the names), derives a decorrelated per-site seed, and accumulates
the ``(E, R)`` pairs the L3 precision controller consumes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.quantize import quantize

# Per-site seed stride (prime): site k hashes from ``seed + k * SITE_STRIDE``.
# Kept small enough that seed + n_sites*stride stays exactly representable in
# f32 (seeds ride through custom_vjp as f32 scalars); the avalanche hash
# decorrelates any seed delta, so the stride only needs to be nonzero.
SITE_STRIDE = 4099
# Offset separating backward-pass noise from forward-pass noise at a site.
BWD_OFFSET = 0x5EED5


def _i32(v):
    return jnp.asarray(v).astype(jnp.int32)


def _quantize_st(x, il, fl, seed, stochastic):
    if stochastic:
        return quantize(x, _i32(il), _i32(fl), _i32(seed), stochastic=True)
    return quantize(x, _i32(il), _i32(fl), _i32(seed), stochastic=False)


def make_qfun(stochastic: bool):
    """Build the STE quantizer for one rounding mode (mode must be static).

    Returns ``qfun(x, il_a, fl_a, il_g, fl_g, seed) -> (q, e, r)`` where all
    scalar args are f32 (simplifies custom_vjp cotangents) and e/r are the
    site's forward-pass stats.
    """

    @jax.custom_vjp
    def qfun(x, il_a, fl_a, il_g, fl_g, seed):
        return _quantize_st(x, il_a, fl_a, seed, stochastic)

    def fwd(x, il_a, fl_a, il_g, fl_g, seed):
        out = _quantize_st(x, il_a, fl_a, seed, stochastic)
        return out, (il_g, fl_g, seed)

    def bwd(res, ct):
        il_g, fl_g, seed = res
        ct_q, _, _ = ct
        gq, _, _ = _quantize_st(
            ct_q, il_g, fl_g, jnp.asarray(seed) + BWD_OFFSET, stochastic
        )
        zero = jnp.zeros((), jnp.float32)
        return (gq, zero, zero, zero, zero, zero)

    qfun.defvjp(fwd, bwd)
    return qfun


_QFUN = {True: make_qfun(True), False: make_qfun(False)}


class QuantCtx:
    """Collects per-site (E, R) stats during tracing of one train/eval step.

    Sites are appended in call order; ``aot.py`` records the resulting
    (name, class) list in the manifest so the Rust controller knows which
    slot of the stat vectors is which.
    """

    def __init__(self, prec, seed, stochastic=True, enabled=True, start=0):
        # prec: f32[6] = [il_w, fl_w, il_a, fl_a, il_g, fl_g]
        # start: global index of this context's first site — a step that uses
        # two contexts (fwd sites inside the autodiff trace, update sites
        # outside) keeps per-site seeds disjoint by continuing the count.
        self.prec = prec
        self.seed = jnp.asarray(seed, jnp.float32)
        self.stochastic = stochastic
        self.enabled = enabled
        self.start = start
        self.names: list[str] = []
        self.classes: list[str] = []
        self.es: list = []
        self.rs: list = []

    # -- internals ---------------------------------------------------------
    def _record(self, name, cls, e, r):
        self.names.append(name)
        self.classes.append(cls)
        self.es.append(e)
        self.rs.append(r)

    # -- public sites ------------------------------------------------------
    def act(self, x, name):
        """Activation site: fwd quantize <ILa,FLa>, bwd quantize <ILg,FLg>."""
        if not self.enabled:
            return x
        k = self.start + len(self.names)
        seed = self.seed + jnp.float32(k * SITE_STRIDE)
        q, e, r = _QFUN[self.stochastic](
            x, self.prec[2], self.prec[3], self.prec[4], self.prec[5], seed
        )
        self._record(name, "act", e, r)
        return q

    def grad(self, g, name):
        """Parameter-gradient site: quantize <ILg,FLg> (no autodiff needed)."""
        if not self.enabled:
            return g
        k = self.start + len(self.names)
        seed = self.seed + jnp.float32(k * SITE_STRIDE)
        q, e, r = _quantize_st(
            jax.lax.stop_gradient(g), self.prec[4], self.prec[5], seed,
            self.stochastic,
        )
        self._record(name, "grad", e, r)
        return q

    def weight(self, w, name):
        """Stored-weight site: quantize <ILw,FLw> after the SGD update."""
        if not self.enabled:
            return w
        k = self.start + len(self.names)
        seed = self.seed + jnp.float32(k * SITE_STRIDE)
        q, e, r = _quantize_st(
            jax.lax.stop_gradient(w), self.prec[0], self.prec[1], seed,
            self.stochastic,
        )
        self._record(name, "weight", e, r)
        return q

    # -- outputs -----------------------------------------------------------
    def stats(self):
        """(evec, rvec) stacked in site order; (len-0-safe for float mode)."""
        if not self.es:
            z = jnp.zeros((1,), jnp.float32)
            return z, z
        return jnp.stack(self.es), jnp.stack(self.rs)

    def site_list(self):
        return list(zip(self.names, self.classes))
