"""L2: the paper's training workloads as quantized JAX graphs.

Two networks:

* ``mlp``   — 784-256-10 fully connected (fast path for tests/quickstart).
* ``lenet`` — the paper's evaluation network (Caffe LeNet): conv5x5x20 /
  maxpool2 / conv5x5x50 / maxpool2 / fc500 / fc10 on 28x28x1 inputs.

For each network we build, and ``aot.py`` lowers:

* a **quantized train step** — forward with activation-site rounding,
  backward with gradient rounding (via ``quant.QuantCtx``), Caffe-style
  momentum-SGD update with weight decay, stored-weight rounding, and the
  per-site (E, R) stat vectors the Rust DPS controller consumes;
* a **float32 baseline train step** — identical update rule, no rounding;
* a **quantized eval step** — deterministic round-to-nearest inference
  (stochastic noise is a training-time tool), returning *per-example* loss
  and correctness vectors so L3 can aggregate over the test set while
  masking any wrapped tail entries exactly (test sets whose size is not a
  multiple of the eval batch);
* a **float eval step**.

All steps take *flat* argument lists (params..., mom..., x, y, lr, seed,
prec) so the AOT artifact's parameter order is explicit and recorded in
``manifest.json``.  ``prec`` is ``f32[6] = [ILw, FLw, ILa, FLa, ILg, FLg]``
— a **runtime input**, which is the heart of the design: the Rust
controller re-decides precision every iteration without recompiling.

Update rule (Caffe SGD, the paper's settings):
    v    <- mu * v + lr * (dW + wd * W)
    W    <- Q_w( W - v )
The momentum buffer stays f32: it models the wide accumulator register of
the paper's flexible MAC unit (Na & Mukhopadhyay accumulate wide and round
on writeback).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .quant import QuantCtx

MU = 0.9          # momentum (paper)
WD = 0.0005       # weight decay (paper)
NUM_CLASSES = 10


# ---------------------------------------------------------------------------
# Parameter specs + init
# ---------------------------------------------------------------------------

@dataclass
class ModelSpec:
    name: str
    input_shape: tuple          # per-example, e.g. (784,) or (28, 28, 1)
    params: list = field(default_factory=list)   # [(name, shape)]
    forward: callable = None    # forward(params_list, x, ctx) -> logits

    @property
    def param_names(self):
        return [n for n, _ in self.params]

    @property
    def param_shapes(self):
        return [s for _, s in self.params]


def _he(rng, shape):
    fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    scale = np.sqrt(2.0 / fan_in)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


def _is_bias(name):
    return name.startswith("b") or (len(name) > 1 and name[1] == "b")


def init_params(spec: ModelSpec, seed: int = 0):
    """Deterministic float32 init: He for weights, zeros for biases, ones
    for layernorm gains (``g*``), small-normal positional embeddings."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in spec.params:
        if name.startswith("g"):
            out.append(np.ones(shape, np.float32))
        elif name == "pos":
            out.append((rng.standard_normal(shape) * 0.02).astype(np.float32))
        elif _is_bias(name):
            out.append(np.zeros(shape, np.float32))
        else:
            out.append(_he(rng, shape))
    return out


# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------

def _mlp_forward(params, x, ctx: QuantCtx):
    w1, b1, w2, b2 = params
    x = ctx.act(x, "input")
    a1 = ctx.act(jax.nn.relu(x @ w1 + b1), "fc1")
    logits = ctx.act(a1 @ w2 + b2, "logits")
    return logits


MLP = ModelSpec(
    name="mlp",
    input_shape=(784,),
    params=[("w1", (784, 256)), ("b1", (256,)),
            ("w2", (256, 10)), ("b2", (10,))],
    forward=_mlp_forward,
)


def _conv(x, w, b):
    """VALID NHWC conv + bias (HWIO filters), f32 accumulate."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32,
    )
    return y + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _lenet_forward(params, x, ctx: QuantCtx):
    """Caffe LeNet. Max-pooling of on-grid values stays on-grid, so pool
    outputs need no extra rounding site (DESIGN.md §4)."""
    cw1, cb1, cw2, cb2, fw1, fb1, fw2, fb2 = params
    x = ctx.act(x, "input")
    a1 = _maxpool2(ctx.act(jax.nn.relu(_conv(x, cw1, cb1)), "conv1"))
    a2 = _maxpool2(ctx.act(jax.nn.relu(_conv(a1, cw2, cb2)), "conv2"))
    flat = a2.reshape(a2.shape[0], -1)
    a3 = ctx.act(jax.nn.relu(flat @ fw1 + fb1), "fc1")
    logits = ctx.act(a3 @ fw2 + fb2, "logits")
    return logits


LENET = ModelSpec(
    name="lenet",
    input_shape=(28, 28, 1),
    params=[("cw1", (5, 5, 1, 20)), ("cb1", (20,)),
            ("cw2", (5, 5, 20, 50)), ("cb2", (50,)),
            ("fw1", (800, 500)), ("fb1", (500,)),
            ("fw2", (500, 10)), ("fb2", (10,))],
    forward=_lenet_forward,
)

# ---------------------------------------------------------------------------
# Transformer extension (beyond the paper): shows DPS generalizes past
# convnets.  A 28x28 digit is read as a 28-step sequence of 28-dim row
# vectors (the classic sequential-MNIST setup), so the whole data pipeline
# is reused.  Two pre-LN single-head attention blocks, mean-pool, linear
# head.  LayerNorm stays in float (it models the wide normalization unit;
# its in/outputs pass through activation quantize sites like everything
# else).
# ---------------------------------------------------------------------------

T_DIM = 64
T_HID = 128
T_SEQ = 28


def _ln(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + 1e-5) + b


def _attn(x, wq, wk, wv, wo):
    q = x @ wq
    k = x @ wk
    v = x @ wv
    a = jax.nn.softmax(
        (q @ jnp.swapaxes(k, -1, -2)) * jnp.float32(1.0 / np.sqrt(T_DIM)),
        axis=-1,
    )
    return (a @ v) @ wo


def _transformer_forward(params, x, ctx: QuantCtx):
    it = iter(params)

    def take(n):
        return [next(it) for _ in range(n)]

    (w_in, b_in, pos) = take(3)
    blocks = [take(12) for _ in range(2)]
    (w_out, b_out) = take(2)

    x = x.reshape(x.shape[0], T_SEQ, T_SEQ)  # (B, 28, 28) row sequence
    h = ctx.act(x @ w_in + b_in + pos, "embed")
    for i, blk in enumerate(blocks):
        (wq, wk, wv, wo, g1, bb1, w1, bb2, w2, bb3, g2, bb4) = blk
        a = _attn(_ln(h, g1, bb1), wq, wk, wv, wo)
        h = ctx.act(h + a, f"attn{i}")
        m = jax.nn.relu(_ln(h, g2, bb4) @ w1 + bb2) @ w2 + bb3
        h = ctx.act(h + m, f"mlp{i}")
    pooled = ctx.act(jnp.mean(h, axis=1), "pool")
    logits = ctx.act(pooled @ w_out + b_out, "logits")
    return logits


def _tf_params():
    d, hid, seq = T_DIM, T_HID, T_SEQ
    params = [("w_in", (seq, d)), ("b_in", (d,)), ("pos", (seq, d))]
    for i in range(2):
        params += [
            (f"wq{i}", (d, d)), (f"wk{i}", (d, d)), (f"wv{i}", (d, d)),
            (f"wo{i}", (d, d)),
            (f"g1_{i}", (d,)), (f"bb1_{i}", (d,)),
            (f"w1_{i}", (d, hid)), (f"bb2_{i}", (hid,)),
            (f"w2_{i}", (hid, d)), (f"bb3_{i}", (d,)),
            (f"g2_{i}", (d,)), (f"bb4_{i}", (d,)),
        ]
    params += [("w_out", (d, 10)), ("b_out", (10,))]
    return params


TRANSFORMER = ModelSpec(
    name="transformer",
    input_shape=(28, 28, 1),
    params=_tf_params(),
    forward=_transformer_forward,
)

MODELS = {"mlp": MLP, "lenet": LENET, "transformer": TRANSFORMER}


# ---------------------------------------------------------------------------
# Loss / metrics
# ---------------------------------------------------------------------------

def _xent(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _correct(logits, y):
    return jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(spec: ModelSpec, quantized: bool, stochastic: bool = True):
    """Returns ``fn`` taking flat args:

        params[P], mom[P], x, y, lr, seed, prec

    and returning

        new_params[P], new_mom[P], loss, acc, evec, rvec.

    Float mode emits evec = rvec = f32[1] zeros (manifest: nsites = 0).
    """
    P = len(spec.params)

    def fn(*flat):
        params = list(flat[:P])
        mom = list(flat[P:2 * P])
        x, y, lr, seed, prec = flat[2 * P:]
        y = y.astype(jnp.int32)

        n_act = len(train_step_sites(spec)) - 2 * P if quantized else 0

        def loss_fn(ps):
            # Fwd sites live inside the autodiff trace; only *arrays* may
            # ride out through aux (a ctx object would leak tracers).
            ctx = QuantCtx(prec, seed, stochastic=stochastic, enabled=quantized)
            logits = spec.forward(ps, x, ctx)
            loss = _xent(logits, y)
            return loss, (tuple(ctx.es), tuple(ctx.rs), logits)

        (loss, (act_es, act_rs, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        acc = _correct(logits, y) / jnp.float32(x.shape[0])
        if not quantized:
            # Anchor otherwise-unused inputs: the StableHLO->XlaComputation
            # conversion prunes unused entry parameters, which would change
            # the artifact's signature vs the manifest.  The zero-weight use
            # keeps `seed`/`prec` in the parameter list at no numeric cost.
            loss = loss + 0.0 * (seed + jnp.sum(prec))

        # Update-time sites continue the site numbering after the act sites
        # so per-site noise streams stay disjoint.
        uctx = QuantCtx(prec, seed, stochastic=stochastic, enabled=quantized,
                        start=n_act)
        new_params, new_mom = [], []
        for name, w, v, g in zip(spec.param_names, params, mom, grads):
            if quantized:
                g = uctx.grad(g, f"g_{name}")
            v_new = MU * v + lr * (g + WD * w)
            w_new = w - v_new
            if quantized:
                w_new = uctx.weight(w_new, f"w_{name}")
            new_params.append(w_new)
            new_mom.append(v_new)

        if quantized:
            evec = jnp.stack(list(act_es) + uctx.es)
            rvec = jnp.stack(list(act_rs) + uctx.rs)
        else:
            evec = rvec = jnp.zeros((1,), jnp.float32)
        return tuple(new_params) + tuple(new_mom) + (loss, acc, evec, rvec)

    return fn


def train_step_sites(spec: ModelSpec, quantized: bool = True):
    """Site (name, class) list, in the exact order the step records stats.

    Order: activation sites in forward call order, then per parameter (in
    spec order) its gradient site then its weight site — the order ``fn``
    appends them.
    """
    if not quantized:
        return []
    acts = {"mlp": ["input", "fc1", "logits"],
            "lenet": ["input", "conv1", "conv2", "fc1", "logits"],
            "transformer": ["embed", "attn0", "mlp0", "attn1", "mlp1",
                            "pool", "logits"]}[spec.name]
    sites = [(a, "act") for a in acts]
    for name in spec.param_names:
        sites.append((f"g_{name}", "grad"))
        sites.append((f"w_{name}", "weight"))
    return sites


def make_eval_step(spec: ModelSpec, quantized: bool):
    """Eval over one batch: (params[P], x, y, prec) -> (loss_vec, correct_vec).

    Outputs are *per-example* f32[batch] vectors — the host sums only the
    first `valid` entries of a wrapped tail batch, so test sets whose size
    is not a multiple of the eval batch evaluate exactly (bit-identical to
    a batch-size-1 sweep) instead of approximately rescaling batch sums.

    Round-to-nearest (deterministic) activation quantization; stored weights
    are already on-grid from the train step's weight site.
    """
    P = len(spec.params)

    def fn(*flat):
        params = list(flat[:P])
        x, y, prec = flat[P:]
        y = y.astype(jnp.int32)
        ctx = QuantCtx(prec, jnp.float32(0.0), stochastic=False,
                       enabled=quantized)
        logits = spec.forward(params, x, ctx)
        logp = jax.nn.log_softmax(logits)
        loss_vec = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
        correct_vec = (jnp.argmax(logits, axis=1) == y).astype(jnp.float32)
        if not quantized:
            # keep `prec` in the entry signature (see make_train_step)
            loss_vec = loss_vec + 0.0 * jnp.sum(prec)
        return loss_vec, correct_vec

    return fn


# ---------------------------------------------------------------------------
# Example args for lowering (shapes only)
# ---------------------------------------------------------------------------

def example_args(spec: ModelSpec, batch: int, for_eval: bool = False):
    f32 = jnp.float32
    params = [jax.ShapeDtypeStruct(s, f32) for s in spec.param_shapes]
    x = jax.ShapeDtypeStruct((batch,) + tuple(spec.input_shape), f32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    prec = jax.ShapeDtypeStruct((6,), f32)
    if for_eval:
        return (*params, x, y, prec)
    mom = [jax.ShapeDtypeStruct(s, f32) for s in spec.param_shapes]
    lr = jax.ShapeDtypeStruct((), f32)
    seed = jax.ShapeDtypeStruct((), f32)
    return (*params, *mom, x, y, lr, seed, prec)
