"""AOT build: lower every L2 graph to HLO **text** + write the manifest.

This is the only place Python touches the system; it runs once at build
time (``make artifacts``) and the Rust coordinator is self-contained
afterwards.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (under ``artifacts/``):

* ``<module>.hlo.txt``      — one per lowered graph (see ``MODULES``)
* ``<model>_params.npz``    — deterministic initial parameters (He init)
* ``manifest.json``         — for every module: input/output names, shapes,
  dtypes, quantize-site list (name + class, in stat-vector order), model
  metadata (param names/shapes, input shape, batch).  The Rust runtime is
  entirely manifest-driven; nothing about argument order is hard-coded on
  the Rust side.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.quantize import quantize
from .kernels.qmatmul import qmatmul

TRAIN_BATCH = 64     # paper: batch size 64
EVAL_BATCH = 100     # divides the canonical 10k test set


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (xla_extension-0.5.1-safe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def _train_io(spec: M.ModelSpec, quantized: bool):
    """(inputs, outputs) descriptors for a train-step module."""
    ins, outs = [], []
    for n, s in spec.params:
        ins.append({"name": n, **_spec(s)})
    for n, s in spec.params:
        ins.append({"name": f"m_{n}", **_spec(s)})
    ins.append({"name": "x", **_spec((TRAIN_BATCH,) + tuple(spec.input_shape))})
    ins.append({"name": "y", **_spec((TRAIN_BATCH,), ), "dtype": "i32"})
    ins.append({"name": "lr", **_spec(())})
    ins.append({"name": "seed", **_spec(())})
    ins.append({"name": "prec", **_spec((6,))})
    nsites = len(M.train_step_sites(spec)) if quantized else 0
    for n, s in spec.params:
        outs.append({"name": n, **_spec(s)})
    for n, s in spec.params:
        outs.append({"name": f"m_{n}", **_spec(s)})
    outs.append({"name": "loss", **_spec(())})
    outs.append({"name": "acc", **_spec(())})
    outs.append({"name": "evec", **_spec((max(nsites, 1),))})
    outs.append({"name": "rvec", **_spec((max(nsites, 1),))})
    return ins, outs


def _eval_io(spec: M.ModelSpec):
    ins = [{"name": n, **_spec(s)} for n, s in spec.params]
    ins.append({"name": "x", **_spec((EVAL_BATCH,) + tuple(spec.input_shape))})
    ins.append({"name": "y", **_spec((EVAL_BATCH,)), "dtype": "i32"})
    ins.append({"name": "prec", **_spec((6,))})
    # per-example vectors: the host masks wrapped tail entries exactly
    # (the Rust engine detects "loss_vec" and switches to exact accumulation)
    outs = [{"name": "loss_vec", **_spec((EVAL_BATCH,))},
            {"name": "correct_vec", **_spec((EVAL_BATCH,))}]
    return ins, outs


def _quantize_module(n, stochastic):
    """Standalone quantizer (parity tests + L1 benches from Rust)."""
    def fn(x, il, fl, seed):
        return quantize(x, il, fl, seed, stochastic=stochastic)
    args = (
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    ins = [{"name": "x", **_spec((n,))},
           {"name": "il", **_spec(()), "dtype": "i32"},
           {"name": "fl", **_spec(()), "dtype": "i32"},
           {"name": "seed", **_spec(()), "dtype": "i32"}]
    outs = [{"name": "q", **_spec((n,))},
            {"name": "e", **_spec(())},
            {"name": "r", **_spec(())}]
    return fn, args, ins, outs


def _qmatmul_module(m, k, n):
    def fn(a, b, prec, seed):
        prec = prec.astype(jnp.int32)
        return (qmatmul(a, b, prec[0], prec[1], prec[2], prec[3], seed),)
    args = (
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    ins = [{"name": "a", **_spec((m, k))},
           {"name": "b", **_spec((k, n))},
           {"name": "prec", **_spec((4,))},
           {"name": "seed", **_spec(()), "dtype": "i32"}]
    outs = [{"name": "c", **_spec((m, n))}]
    return fn, args, ins, outs


def build_modules():
    """name -> (fn, example_args, manifest entry)."""
    mods = {}
    for mname, spec in M.MODELS.items():
        for kind, quantized, stochastic in (
            ("train", True, True),
            ("train_nearest", True, False),
            ("train_float", False, True),
        ):
            fn = M.make_train_step(spec, quantized=quantized,
                                   stochastic=stochastic)
            args = M.example_args(spec, TRAIN_BATCH)
            ins, outs = _train_io(spec, quantized)
            sites = M.train_step_sites(spec) if quantized else []
            mods[f"{mname}_{kind}"] = (fn, args, {
                "kind": "train", "model": mname, "batch": TRAIN_BATCH,
                "quantized": quantized, "stochastic": stochastic,
                # params + momenta (the first 2P entry parameters) are
                # donated to the matching outputs: PJRT may alias the
                # buffers in place, so a device-resident step allocates
                # nothing for state
                "donated": True,
                "inputs": ins, "outputs": outs,
                "sites": [{"name": n, "class": c} for n, c in sites],
            })
        for kind, quantized in (("eval", True), ("eval_float", False)):
            fn = M.make_eval_step(spec, quantized=quantized)
            args = M.example_args(spec, EVAL_BATCH, for_eval=True)
            ins, outs = _eval_io(spec)
            mods[f"{mname}_{kind}"] = (fn, args, {
                "kind": "eval", "model": mname, "batch": EVAL_BATCH,
                "quantized": quantized, "stochastic": False,
                "inputs": ins, "outputs": outs, "sites": [],
            })

    for n in (4096, 131072):
        for tag, st in (("sr", True), ("rn", False)):
            fn, args, ins, outs = _quantize_module(n, st)
            mods[f"quantize_{tag}_{n}"] = (fn, args, {
                "kind": "quantize", "model": None, "batch": n,
                "quantized": True, "stochastic": st,
                "inputs": ins, "outputs": outs, "sites": [],
            })

    fn, args, ins, outs = _qmatmul_module(256, 256, 256)
    mods["qmatmul_256"] = (fn, args, {
        "kind": "qmatmul", "model": None, "batch": 256,
        "quantized": True, "stochastic": True,
        "inputs": ins, "outputs": outs, "sites": [],
    })
    return mods


def model_meta():
    return {
        name: {
            "params": [{"name": n, "shape": list(s)} for n, s in spec.params],
            "input_shape": list(spec.input_shape),
            "num_classes": M.NUM_CLASSES,
        }
        for name, spec in M.MODELS.items()
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated module-name substrings to rebuild")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    mods = build_modules()
    manifest = {"modules": {}, "models": model_meta(),
                "train_batch": TRAIN_BATCH, "eval_batch": EVAL_BATCH}

    for name, (fn, eargs, meta) in mods.items():
        if args.only and not any(s in name for s in args.only.split(",")):
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        print(f"[aot] lowering {name} ...", flush=True)
        donate = ()
        if meta.get("donated"):
            # donate params + momenta (the first 2P flat args) so XLA emits
            # input-output aliasing for the state tensors
            donate = tuple(range(2 * len(M.MODELS[meta["model"]].params)))
        lowered = jax.jit(fn, donate_argnums=donate).lower(*eargs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = f"{name}.hlo.txt"
        manifest["modules"][name] = meta
        print(f"[aot]   wrote {path} ({len(text) / 1e6:.2f} MB)")

    for mname, spec in M.MODELS.items():
        params = M.init_params(spec, seed=0)
        path = os.path.join(args.out_dir, f"{mname}_params.npz")
        np.savez(path, **{n: p for (n, _), p in zip(spec.params, params)})
        print(f"[aot] wrote {path}")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {mpath} ({len(manifest['modules'])} modules)")


if __name__ == "__main__":
    main()
