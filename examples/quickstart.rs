//! Quickstart: train a small MLP with quantization-error-driven dynamic
//! precision scaling, then print the headline numbers.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use qedps::config::ExperimentConfig;
use qedps::runtime::Runtime;
use qedps::trainer::run_experiment;

fn main() -> anyhow::Result<()> {
    qedps::util::logging::init();

    // The paper's hyperparameters, scaled to a 30-second demo.
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.scheme = "qedps".into(); // the paper's Algorithm 2
    cfg.iters = 400;
    cfg.train_n = 6_000;
    cfg.test_n = 1_000;
    cfg.eval_every = 100;
    cfg.log_every = 10;

    let mut rt = Runtime::create()?;
    let hist = run_experiment(&mut rt, &cfg)?;
    let s = hist.summary();

    println!("\n==== quickstart: {} + {} ====", cfg.model, cfg.scheme);
    println!("test accuracy      : {:.2}% (best {:.2}%)",
             100.0 * s.final_test_acc, 100.0 * s.best_test_acc);
    println!("mean weight bits   : {:.1}   (fp32 baseline: 32)", s.mean_weight_bits);
    println!("mean act bits      : {:.1}", s.mean_act_bits);
    println!("mean grad bits     : {:.1}", s.mean_grad_bits);
    println!("min weight bits    : {}", s.min_weight_bits);
    println!("mean step time     : {:.1} ms", s.mean_step_ms);

    // What those bits buy on the paper's target hardware:
    let speedup = qedps::coordinator::figures::history_speedup(&rt, &cfg.model, &hist)?;
    println!("flexible-MAC speedup vs 32-bit: {speedup:.2}x");
    Ok(())
}
