//! Fault recovery: inject a bit-flip and a forced-NaN loss into a short
//! training run and watch the resilience harness ride through both —
//! checkpoint rollback, precision escalation, deterministic replay.
//!
//! ```bash
//! make artifacts && cargo run --release --example fault_recovery
//! ```
//!
//! Equivalent CLI invocation:
//!
//! ```bash
//! repro train --model mlp --scheme qedps --iters 120 \
//!     --checkpoint-dir /tmp/qedps_demo_ckpt \
//!     --fault bitflip@40:weight --fault nan@70
//! ```

use qedps::config::ExperimentConfig;
use qedps::runtime::Runtime;
use qedps::trainer::run_experiment;

fn main() -> anyhow::Result<()> {
    qedps::util::logging::init();

    let ckpt_dir = std::env::temp_dir().join("qedps_fault_recovery_ckpt");
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.scheme = "qedps".into();
    cfg.iters = 120;
    cfg.train_n = 2_000;
    cfg.test_n = 500;
    cfg.eval_every = 0;
    cfg.log_every = 5;
    cfg.checkpoint_dir = Some(ckpt_dir.to_string_lossy().into_owned());
    cfg.checkpoint_every = 20;
    // the fault plan: corrupt a weight tensor at iter 40, then force the
    // observed loss to NaN at iter 70 — both one-shot and seeded
    cfg.faults = vec!["bitflip@40:weight".into(), "nan@70".into()];
    cfg.fault_seed = 7;
    cfg.recovery_backoff = 5;

    let mut rt = Runtime::create()?;
    let hist = run_experiment(&mut rt, &cfg)?;
    let s = hist.summary();

    println!("\n==== fault_recovery: {} + {} ====", cfg.model, cfg.scheme);
    println!("status             : {}", s.status.as_str());
    println!("recoveries         : {}", s.recoveries);
    println!("final train loss   : {:.4}", s.final_train_loss);
    println!("final test acc     : {:.2}%", 100.0 * s.final_test_acc);
    println!("\nrecovery trail:");
    for e in &hist.recovery {
        match e.rollback_to {
            Some(to) => println!(
                "  iter {:>4}  {:<18} -> rolled back to iter {to}  ({})",
                e.iter, e.kind, e.detail
            ),
            None => println!("  iter {:>4}  {:<18}    ({})", e.iter, e.kind, e.detail),
        }
    }
    anyhow::ensure!(
        s.status.as_str() == "ok" && s.final_train_loss.is_finite(),
        "demo run did not recover cleanly"
    );
    println!("\nrun survived both faults; records under {}", cfg.out_dir);
    Ok(())
}
