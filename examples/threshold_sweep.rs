//! §5 limitation study: `E_max` / `R_max` act as new hyperparameters that
//! control how aggressively Algorithm 2 starves the bit-width.  This sweep
//! quantifies the accuracy-vs-bits trade-off the paper describes
//! qualitatively: too-loose thresholds waste bits, too-tight ones stall or
//! destabilize training.
//!
//! ```bash
//! cargo run --release --example threshold_sweep
//! ```

use qedps::config::ExperimentConfig;
use qedps::runtime::Runtime;
use qedps::trainer::run_experiment;

fn main() -> anyhow::Result<()> {
    qedps::util::logging::init();
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    let mut rt = Runtime::create()?;

    println!("{:>10} {:>10} {:>9} {:>8} {:>8} {:>8}",
             "E_max", "R_max", "acc", "w_bits", "a_bits", "g_bits");
    println!("{}", "-".repeat(58));
    for e_max in [1e-2f64, 1e-3, 1e-4, 1e-5] {
        for r_max in [1e-2f64, 1e-4] {
            let mut cfg = ExperimentConfig::default();
            cfg.model = "mlp".into();
            cfg.scheme = "qedps".into();
            cfg.iters = 300;
            cfg.train_n = 5_000;
            cfg.test_n = 1_000;
            cfg.eval_every = 0;
            cfg.log_every = 5;
            cfg.e_max = e_max;
            cfg.r_max = r_max;
            let hist = run_experiment(&mut rt, &cfg)?;
            let s = hist.summary();
            println!("{e_max:>10.0e} {r_max:>10.0e} {:>9.4} {:>8.1} {:>8.1} {:>8.1}",
                     s.final_test_acc, s.mean_weight_bits, s.mean_act_bits,
                     s.mean_grad_bits);
        }
    }
    println!("\nexpected shape (paper §5): accuracy holds until the thresholds");
    println!("get too aggressive (large E_max), then convergence degrades while");
    println!("bit-width shrinks — the thresholds are real hyperparameters.");
    Ok(())
}
