//! Extension beyond the paper: dynamic precision scaling on a
//! **transformer** (2-block pre-LN attention over the 28 image rows as a
//! sequence — "sequential MNIST").  Demonstrates the controller + runtime
//! are architecture-agnostic: the manifest drives everything, so a new L2
//! model needs zero Rust changes.
//!
//! ```bash
//! cargo run --release --example transformer_dps
//! ```

use qedps::config::ExperimentConfig;
use qedps::runtime::Runtime;
use qedps::trainer::run_experiment;

fn main() -> anyhow::Result<()> {
    qedps::util::logging::init();

    let mut rt = Runtime::create()?;
    let mut results = Vec::new();
    for scheme in ["qedps", "float"] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = "transformer".into();
        cfg.scheme = scheme.into();
        cfg.iters = std::env::var("ITERS").ok().and_then(|s| s.parse().ok())
            .unwrap_or(300);
        cfg.train_n = 6_000;
        cfg.test_n = 1_000;
        cfg.eval_every = 100;
        cfg.log_every = 10;
        let hist = qedps::coordinator::run_and_record(
            &mut rt, &cfg, &format!("transformer_{scheme}"))?;
        results.push((scheme, hist.summary()));
    }
    let _ = run_experiment; // (direct API also available)

    println!("\n==== transformer + DPS (extension) ====");
    for (scheme, s) in &results {
        println!(
            "{scheme:<6}: acc={:.4}  bits(w/a/g)={:.1}/{:.1}/{:.1}  step={:.0} ms",
            s.final_test_acc, s.mean_weight_bits, s.mean_act_bits,
            s.mean_grad_bits, s.mean_step_ms
        );
    }
    println!("\nreading: the same Algorithm-2 controller that drives LeNet finds");
    println!("a reduced-precision operating point for attention blocks too —");
    println!("the technique is not convnet-specific.");
    Ok(())
}
