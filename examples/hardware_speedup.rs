//! §6 hardware claim: turn measured bit-width trajectories into training
//! speedup on Na & Mukhopadhyay's flexible MAC unit (cycle model).
//!
//! Runs a short qedps training to get a *real* trajectory, then prices it
//! — and a sweep of static word lengths — on the MAC model.
//!
//! ```bash
//! cargo run --release --example hardware_speedup
//! ```

use qedps::config::ExperimentConfig;
use qedps::coordinator::figures;
use qedps::fixedpoint::Format;
use qedps::macsim::{self, MacUnit};
use qedps::policy::PrecState;
use qedps::runtime::Runtime;
use qedps::trainer::run_experiment;

fn main() -> anyhow::Result<()> {
    qedps::util::logging::init();
    let mut rt = Runtime::create()?;

    // static sweep (the MAC's ideal-case table)
    let unit = MacUnit::default();
    println!("flexible MAC (8x8 granules): static word-length sweep");
    println!("{:>6} {:>10}", "bits", "speedup");
    for bits in [32, 24, 20, 16, 14, 12, 8] {
        println!("{bits:>6} {:>9.2}x", unit.speedup_vs_32(bits));
    }

    // measured trajectory
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.iters = 400;
    cfg.train_n = 6_000;
    cfg.test_n = 1_000;
    cfg.eval_every = 0;
    cfg.log_every = 1; // dense trajectory for accurate pricing
    let hist = run_experiment(&mut rt, &cfg)?;

    let layers = figures::model_layers(&rt, &cfg.model)?;
    let traj: Vec<PrecState> = hist.train.iter().map(|r| r.prec).collect();
    let speedup = macsim::trajectory_speedup(&unit, &layers, &traj);
    let s = hist.summary();
    println!("\nmeasured qedps trajectory ({} iters):", cfg.iters);
    println!("  mean bits (w/a/g): {:.1}/{:.1}/{:.1}",
             s.mean_weight_bits, s.mean_act_bits, s.mean_grad_bits);
    println!("  training speedup on flexible MAC vs fp32: {speedup:.2}x");
    println!("  (paper §6: lower bit-width than Na & Mukhopadhyay => larger speedup)");

    // what-if: the paper's headline averages
    let headline = PrecState {
        weights: Format::new(2, 14),
        acts: Format::new(2, 12),
        grads: Format::new(8, 16),
    };
    let cyc = macsim::iteration_cycles(&unit, &layers, &headline);
    let base = macsim::iteration_cycles(&unit, &layers,
                                        &PrecState::uniform(Format::new(16, 16)));
    println!("\npaper-headline precision (16b w / 14b a / 24b g): {:.2}x",
             base as f64 / cyc as f64);
    Ok(())
}
