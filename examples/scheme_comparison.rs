//! Table-1 head-to-head: every precision-scaling scheme the paper discusses
//! on the identical workload — this paper's qedps, Na & Mukhopadhyay's
//! convergence-based DPS, Courbariaux's fixed-width dynamic radix, Gupta's
//! static <8,8>, the naive fixed-13, and the fp32 baseline.
//!
//! ```bash
//! cargo run --release --example scheme_comparison            # mlp, fast
//! MODEL=lenet ITERS=3000 cargo run --release --example scheme_comparison
//! ```

use qedps::config::ExperimentConfig;
use qedps::coordinator;
use qedps::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    qedps::util::logging::init();

    let mut cfg = ExperimentConfig::default();
    cfg.model = std::env::var("MODEL").unwrap_or_else(|_| "mlp".into());
    cfg.iters = std::env::var("ITERS").ok().and_then(|s| s.parse().ok())
        .unwrap_or(600);
    cfg.train_n = 8_000;
    cfg.test_n = 1_000;
    cfg.eval_every = cfg.iters / 4;
    cfg.log_every = 10;

    let schemes = ["qedps", "na", "courbariaux", "gupta88", "fixed13",
                   "schedule", "float"];
    let mut rt = Runtime::create()?;
    let rows = coordinator::compare_schemes(&mut rt, &cfg, &schemes)?;
    coordinator::print_compare_table(&rows);

    println!("expected shape (paper Table 1 + §6):");
    println!("  - qedps converges at the lowest mean weight/act bits of the DPS schemes");
    println!("  - fixed13 fails to converge (or lags badly)");
    println!("  - float32 sets the accuracy reference at 32 bits");
    println!("  - qedps hw_speedup > na's (lower bits on the flexible MAC)");
    Ok(())
}
