//! **End-to-end validation run** (EXPERIMENTS.md §E2E): the paper's actual
//! experiment — LeNet on (synthetic-)MNIST, batch 64, inv-decay LR, Alg. 2
//! precision scaling — regenerating Figure 3 (bit-width trajectories) and
//! Figure 4 (accuracy: DPS vs float32 vs fixed-13-bit) in one run.
//!
//! ```bash
//! cargo run --release --example lenet_mnist              # default 1500 iters
//! ITERS=10000 cargo run --release --example lenet_mnist  # paper-scale
//! ```
//!
//! Point `MNIST_DIR` at the real IDX files to run on actual MNIST.

use qedps::config::ExperimentConfig;
use qedps::coordinator::figures;
use qedps::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    qedps::util::logging::init();

    let iters: u64 = std::env::var("ITERS").ok().and_then(|s| s.parse().ok())
        .unwrap_or(1500);
    let mut cfg = ExperimentConfig::default();
    cfg.model = "lenet".into();
    cfg.iters = iters;
    cfg.train_n = 10_000;
    cfg.test_n = 2_000;
    cfg.eval_every = (iters / 10).max(1);
    cfg.log_every = 10;

    let mut rt = Runtime::create()?;

    println!("=== Figure 3: qedps bit-width trajectories (LeNet, {iters} iters) ===");
    let hist = figures::fig3(&mut rt, &cfg)?;

    println!("\n=== Figure 4: accuracy — qedps vs float vs fixed-13 ===");
    let runs = figures::fig4(&mut rt, &cfg)?;

    // headline summary (paper: 98.8% @ ~16-bit weights / ~14-bit acts)
    let s = hist.summary();
    let float_acc = runs
        .iter()
        .find(|(n, _)| n == "float")
        .map(|(_, h)| h.summary().final_test_acc)
        .unwrap_or(0.0);
    let fixed_acc = runs
        .iter()
        .find(|(n, _)| n == "fixed13")
        .map(|(_, h)| h.summary().final_test_acc)
        .unwrap_or(0.0);
    let speedup = figures::history_speedup(&rt, &cfg.model, &hist)?;

    println!("\n==== E2E summary (record in EXPERIMENTS.md) ====");
    println!("qedps   : acc={:.4}  bits(w/a/g)={:.1}/{:.1}/{:.1}  min_w={}",
             s.final_test_acc, s.mean_weight_bits, s.mean_act_bits,
             s.mean_grad_bits, s.min_weight_bits);
    println!("float32 : acc={float_acc:.4}  (paper: DPS within a small margin of this)");
    println!("fixed13 : acc={fixed_acc:.4}  (paper: fails to converge)");
    println!("flexible-MAC speedup of the measured trajectory: {speedup:.2}x");
    println!("CSV series: target/experiments/fig3_lenet_* and fig4_lenet_*");
    Ok(())
}
