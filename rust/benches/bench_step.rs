//! L2/L3 benches: full train-step and eval-step latency per model and
//! variant — the numbers behind every wall-clock claim in EXPERIMENTS.md.
//! The quantized-vs-float delta is the *emulation overhead* (the paper's
//! hardware would pay nothing; we pay the rounding arithmetic).

use qedps::bench::{black_box, BenchOpts};
use qedps::config::ExperimentConfig;
use qedps::data::{synth, Batcher};
use qedps::runtime::Runtime;
use qedps::trainer::Trainer;

fn bench_model(
    rt: &mut Runtime,
    model: &str,
    scheme: &str,
    span_overhead_ns: f64,
) -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.model = model.into();
    cfg.scheme = scheme.into();
    cfg.train_n = 512;
    cfg.test_n = 200;
    let ds = synth::generate(512, 5);
    let mut trainer = Trainer::new(rt, cfg.clone())?;
    let mut batcher = Batcher::new(&ds, trainer.train_batch_size(), 1);
    let mut iter = 0u64;
    let opts = BenchOpts { warmup_iters: 3, min_iters: 10, min_time_s: 2.0 };
    let builds_before = qedps::runtime::literal_builds();
    let xfers_before = qedps::runtime::host_transfers();
    let r = qedps::bench::bench_with(&format!("step/{model}/{scheme}"), &opts, || {
        trainer.fill_batch(&mut batcher);
        iter += 1;
        black_box(trainer.step(iter).unwrap().loss);
    });
    // pinned-input invariant: the timed loop must not construct literals
    anyhow::ensure!(
        qedps::runtime::literal_builds() == builds_before,
        "step/{model}/{scheme} built literals inside the hot loop"
    );
    // device-residency invariant: params/momenta stay on device, so the
    // timed loop performs zero host<->device state transfers (the literal
    // fallback path is legitimately nonzero — skip the assert there)
    if trainer.device_resident() {
        anyhow::ensure!(
            qedps::runtime::host_transfers() == xfers_before,
            "step/{model}/{scheme} copied state across host<->device inside the hot loop"
        );
    }
    // telemetry invariant: the ~6 spans on the step path must cost no more
    // than 2% of the step itself when no trace sink is attached
    anyhow::ensure!(
        span_overhead_ns * 6.0 <= r.mean_ns * 0.02,
        "step/{model}/{scheme}: telemetry span overhead {:.0} ns exceeds 2% of \
         the {:.0} ns step",
        span_overhead_ns * 6.0,
        r.mean_ns
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    let mut rt = Runtime::create()?;
    println!("== bench_step (train/eval step latency) ==");

    // price one span create+drop (no sink) so every step bench below can
    // assert the instrumentation stays inside its 2% budget
    let span_opts = BenchOpts { warmup_iters: 100, min_iters: 10_000, min_time_s: 0.0 };
    let span_r = qedps::bench::bench_with("telemetry span create+drop", &span_opts, || {
        let _s = qedps::telemetry::span!("bench.span_probe");
        black_box(&_s);
    });

    for model in ["mlp", "lenet"] {
        for scheme in ["qedps", "na", "float"] {
            // qedps => stochastic artifact, na => nearest, float => float
            bench_model(&mut rt, model, scheme, span_r.mean_ns)?;
        }
    }

    // eval latency (full test-set pass / per batch)
    for model in ["mlp", "lenet"] {
        let mut cfg = ExperimentConfig::default();
        cfg.model = model.into();
        let test = synth::generate(500, 6);
        let mut trainer = Trainer::new(&mut rt, cfg)?;
        let opts = BenchOpts { warmup_iters: 1, min_iters: 5, min_time_s: 1.0 };
        qedps::bench::bench_with(&format!("eval/{model}/500-images"), &opts, || {
            black_box(trainer.evaluate(&test).unwrap());
        });
    }
    Ok(())
}
