//! Data-pipeline benches: synthetic digit generation and batch filling.
//! DESIGN §7 target: generation >= 10^6 images/s is NOT expected (each image
//! rasterizes ~50 segments x 784 pixels); the real target is that batch
//! *filling* (the hot-loop part) is memcpy-speed and generation is a one-off
//! startup cost far below training time.

use qedps::bench::{black_box, bench, report_throughput, BenchOpts};
use qedps::data::{synth, Batcher, IMG_PIXELS};

fn main() {
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    println!("== bench_data (pipeline) ==");

    let opts = BenchOpts { warmup_iters: 1, min_iters: 5, min_time_s: 1.0 };
    let r = qedps::bench::bench_with("synth/generate-1000", &opts, || {
        black_box(synth::generate(1000, 42).n);
    });
    report_throughput(&r, 1000);

    let ds = synth::generate(10_000, 1);
    let mut b = Batcher::new(&ds, 64, 2);
    let mut x = vec![0.0f32; 64 * IMG_PIXELS];
    let mut y = vec![0i32; 64];
    let r = bench("batcher/fill-64", || {
        b.next_into(&mut x, &mut y);
        black_box(x[0]);
    });
    report_throughput(&r, 64);

    // IDX round-trip (startup path)
    let dir = std::env::temp_dir().join("qedps_bench_idx");
    std::fs::create_dir_all(&dir).unwrap();
    let small = synth::generate(1000, 3);
    let path = dir.join("imgs.idx");
    let r = qedps::bench::bench_with("idx/write-1000", &opts, || {
        qedps::data::mnist::write_idx_images(&path, &small).unwrap();
    });
    report_throughput(&r, 1000);
}
