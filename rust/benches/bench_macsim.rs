//! MAC-simulator benches: cycle-model pricing (used once per logged step)
//! and the exact-arithmetic execution path (used by validation tests).

use qedps::bench::{bench, black_box, report_throughput};
use qedps::fixedpoint::{quantize_slice, Format, RoundMode};
use qedps::macsim::{self, MacUnit};
use qedps::policy::PrecState;
use qedps::util::rng::Pcg32;

fn main() {
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    println!("== bench_macsim ==");
    let unit = MacUnit::default();
    let layers = macsim::layer_costs(
        &[
            ("cw1", vec![5, 5, 1, 20]),
            ("cw2", vec![5, 5, 20, 50]),
            ("fw1", vec![800, 500]),
            ("fw2", vec![500, 10]),
        ],
        (28, 28),
        64,
    );

    let mut bits = 4i32;
    bench("macsim/iteration_cycles(lenet)", || {
        bits = 4 + (bits + 1) % 20;
        let p = PrecState::uniform(Format::new(bits / 2 + 1, bits - bits / 2 - 1));
        black_box(macsim::iteration_cycles(&unit, &layers, &p));
    });

    let traj: Vec<PrecState> =
        (0..3000).map(|i| PrecState::uniform(Format::new(2, 6 + (i % 12) as i32))).collect();
    bench("macsim/trajectory_speedup(3000 iters)", || {
        black_box(macsim::trajectory_speedup(&unit, &layers, &traj));
    });

    // exact integer-MAC execution (validation path)
    let mut rng = Pcg32::seeded(4);
    let fmt = Format::new(4, 8);
    let a: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
    let w: Vec<f32> = (0..4096).map(|_| rng.normal() as f32 * 0.1).collect();
    let (qa, _) = quantize_slice(&a, fmt, 1, RoundMode::Stochastic);
    let (qw, _) = quantize_slice(&w, fmt, 2, RoundMode::Stochastic);
    let r = bench("macsim/execute_dot-4096", || {
        black_box(unit.execute_dot(&qa, &qw, fmt, fmt).0);
    });
    report_throughput(&r, 4096);
}
