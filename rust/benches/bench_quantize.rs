//! L1 benches: the quantize hot-spot — AOT Pallas artifact vs the Rust
//! software mirror, stochastic vs nearest (Gupta et al.'s "negligible
//! overhead" claim), plus the quantized matmul.

use qedps::bench::{bench, black_box, report_throughput};
use qedps::fixedpoint::{quantize_slice_at, Format, RoundMode};
use qedps::runtime::{literal_f32, Runtime};
use qedps::util::rng::Pcg32;
use xla::Literal;

fn randvec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.normal() as f32 * 2.0).collect()
}

fn main() -> anyhow::Result<()> {
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    let mut rt = Runtime::create()?;
    println!("== bench_quantize (L1 hot-spot) ==");

    for (module, n) in [("quantize_sr_4096", 4096usize),
                        ("quantize_sr_131072", 131072),
                        ("quantize_rn_131072", 131072)] {
        let exe = rt.load(module)?;
        let x = randvec(n, 7);
        let xl = literal_f32(&x, &[n])?;
        let il = Literal::scalar(4i32);
        let fl = Literal::scalar(10i32);
        let mut seed = 0i32;
        let r = bench(&format!("hlo/{module}"), || {
            seed += 1;
            let s = Literal::scalar(seed);
            let outs = exe.run(&[&xl, &il, &fl, &s]).unwrap();
            black_box(outs[1].get_first_element::<f32>().unwrap());
        });
        report_throughput(&r, n);
    }

    // Rust mirror (policy-side / macsim-side quantizer)
    for n in [4096usize, 131072] {
        let x = randvec(n, 9);
        let mut out = Vec::new();
        let fmt = Format::new(4, 10);
        let mut seed = 0;
        let r = bench(&format!("rust/quantize_sr_{n}"), || {
            seed += 1;
            let s = quantize_slice_at(&x, 0, fmt, seed, RoundMode::Stochastic,
                                      &mut out);
            black_box(s.e);
        });
        report_throughput(&r, n);
        let mut seed = 0;
        let r = bench(&format!("rust/quantize_rn_{n}"), || {
            seed += 1;
            let s = quantize_slice_at(&x, 0, fmt, seed, RoundMode::Nearest,
                                      &mut out);
            black_box(s.e);
        });
        report_throughput(&r, n);
    }

    // quantized matmul artifact (the MAC-pipeline demo)
    {
        let exe = rt.load("qmatmul_256")?;
        let a = literal_f32(&randvec(256 * 256, 11), &[256, 256])?;
        let b = literal_f32(&randvec(256 * 256, 12), &[256, 256])?;
        let prec = literal_f32(&[4.0, 10.0, 4.0, 10.0], &[4])?;
        let seed = Literal::scalar(3i32);
        let r = bench("hlo/qmatmul_256", || {
            let outs = exe.run(&[&a, &b, &prec, &seed]).unwrap();
            black_box(outs[0].element_count());
        });
        // 2*M*N*K flops
        let flops = 2.0 * 256.0f64.powi(3);
        println!("{:<44} {:>9.2} GFLOP/s",
                 "hlo/qmatmul_256 (flops)", flops / (r.mean_ns / 1e9) / 1e9);
    }
    Ok(())
}
