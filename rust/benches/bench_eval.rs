//! Eval-pass bench: the flat-counter proof behind the cached eval set.
//!
//! After one warmup pass per trainer, every timed eval pass must perform
//! zero literal constructions and zero host→device input uploads
//! (`device.h2d_input`) — the test set is batched and resident from pass
//! one.  With device-resident parameters the pass must additionally be
//! free of state uploads (`device.h2d_state`) and counted host transfers.
//! The legacy per-pass refill path (`runtime.eval_set = false`) runs
//! alongside for the removed-cost comparison and must agree bit-for-bit.

use qedps::bench::{black_box, BenchOpts};
use qedps::config::ExperimentConfig;
use qedps::data::synth;
use qedps::runtime::Runtime;
use qedps::trainer::Trainer;

fn bench_model(rt: &mut Runtime, model: &str) -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.model = model.into();
    // not a multiple of the eval batch: the tail-mask path stays exercised
    let test = synth::generate(333, 6);
    let opts = BenchOpts { warmup_iters: 0, min_iters: 5, min_time_s: 1.0 };

    let mut cached = Trainer::new(rt, cfg.clone())?;
    // warmup builds the eval set and uploads each batch's inputs once
    black_box(cached.evaluate(&test)?);
    let builds_before = qedps::runtime::literal_builds();
    let xfers_before = qedps::runtime::host_transfers();
    let h2d_state_before = qedps::telemetry::counter("device.h2d_state");
    let h2d_input_before = qedps::telemetry::counter("device.h2d_input");
    let set_builds_before = qedps::telemetry::counter("eval.set_builds");
    let cached_pass = qedps::bench::bench_with(
        &format!("eval/{model}/333-images (cached set)"),
        &opts,
        || {
            black_box(cached.evaluate(&test).unwrap());
        },
    );

    // steady-state invariants: the cache makes every timed pass prep-free
    anyhow::ensure!(
        qedps::runtime::literal_builds() == builds_before,
        "eval/{model}: cached-set pass built literals"
    );
    anyhow::ensure!(
        qedps::telemetry::counter("device.h2d_input") == h2d_input_before,
        "eval/{model}: cached-set pass uploaded input buffers"
    );
    anyhow::ensure!(
        qedps::telemetry::counter("eval.set_builds") == set_builds_before,
        "eval/{model}: eval set was rebuilt inside the timed loop"
    );
    if cached.device_resident() {
        anyhow::ensure!(
            qedps::telemetry::counter("device.h2d_state") == h2d_state_before,
            "eval/{model}: device-resident eval uploaded state"
        );
        anyhow::ensure!(
            qedps::runtime::host_transfers() == xfers_before,
            "eval/{model}: device-resident eval performed counted host transfers"
        );
    }

    // the removed cost: re-batch + re-upload on every pass
    let mut cfg_refill = cfg.clone();
    cfg_refill.eval_set = false;
    let mut refill = Trainer::new(rt, cfg_refill)?;
    black_box(refill.evaluate(&test)?);
    let refill_pass = qedps::bench::bench_with(
        &format!("eval/{model}/333-images (per-pass refill)"),
        &opts,
        || {
            black_box(refill.evaluate(&test).unwrap());
        },
    );
    println!(
        "eval/{model}: cached set saves {:.1}% of the refill pass",
        100.0 * (1.0 - cached_pass.mean_ns / refill_pass.mean_ns.max(1e-12))
    );

    let (cl, ca) = cached.evaluate(&test)?;
    let (ll, la) = refill.evaluate(&test)?;
    anyhow::ensure!(
        cl.to_bits() == ll.to_bits() && ca.to_bits() == la.to_bits(),
        "eval/{model}: cached set ({cl}, {ca}) != refill ({ll}, {la})"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    let mut rt = Runtime::create()?;
    println!("== bench_eval (eval-pass latency, flat-counter invariants) ==");
    for model in ["mlp", "lenet"] {
        bench_model(&mut rt, model)?;
    }
    println!("ok: steady-state eval passes are literal-free and input-upload-free");
    Ok(())
}
