//! L3 controller benches: per-iteration cost of every precision policy.
//! The controller runs once per training step — it must be measured in
//! nanoseconds, not microseconds, to keep L3 overhead <5% (DESIGN §7).

use qedps::bench::{bench, black_box};
use qedps::policy::{make_policy, ClassStats, Feedback, PolicyOptions};
use qedps::util::rng::Pcg32;

fn main() {
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    println!("== bench_policy (controller update cost) ==");
    let opts = PolicyOptions::default();
    for scheme in ["qedps", "na", "courbariaux", "fixed", "float", "schedule"] {
        let mut p = make_policy(scheme, &opts).unwrap();
        let mut st = p.init();
        let mut rng = Pcg32::seeded(3);
        let mut iter = 0u64;
        bench(&format!("policy/{scheme}"), || {
            // fresh feedback each call so branch predictors see real work
            let s = ClassStats { e: rng.next_f32() * 1e-3, r: rng.next_f32() * 1e-3 };
            let fb = Feedback { iter, loss: 1.0 / (iter + 1) as f32,
                                weights: s, acts: s, grads: s };
            iter += 1;
            st = p.update(st, &fb);
            black_box(st.weights.bits());
        });
    }

    // stat aggregation (runs per step over per-site vectors)
    let vals: Vec<f32> = (0..21).map(|i| i as f32 * 1e-4).collect();
    for agg in [qedps::policy::AggMode::Mean, qedps::policy::AggMode::Max,
                qedps::policy::AggMode::Last] {
        bench(&format!("agg/{agg:?}/21-sites"), || {
            black_box(agg.collapse(&vals));
        });
    }
}
