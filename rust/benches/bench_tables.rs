//! Paper-artifact regeneration in bench form: one section per table/figure
//! (DESIGN.md §3), on a reduced budget so `cargo bench` finishes in
//! minutes.  Full-scale regeneration is `repro figures` / `repro compare`
//! and `examples/lenet_mnist.rs`; EXPERIMENTS.md records the full runs.
//!
//! Sections:
//!   [Fig 3]   qedps bit-width trajectory (mlp, reduced iters)
//!   [Fig 4]   accuracy: qedps vs float vs fixed13
//!   [Table 1] scheme head-to-head rows
//!   [Eq 1/2]  stochastic vs nearest rounding A/B
//!   [§6]      measured-trajectory hardware speedup
//!   [ablation] stat-aggregation mode (mean/max/last)

use qedps::config::ExperimentConfig;
use qedps::coordinator::{self, figures};
use qedps::runtime::Runtime;
use qedps::trainer::run_experiment;
use qedps::util::Stopwatch;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.iters = 250;
    cfg.train_n = 4_000;
    cfg.test_n = 1_000;
    cfg.eval_every = 125;
    cfg.log_every = 5;
    cfg
}

fn main() -> anyhow::Result<()> {
    qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    let mut rt = Runtime::create()?;
    let total = Stopwatch::start();

    println!("== bench_tables: paper artifacts on a reduced budget ==\n");

    // ---- Fig 3 ---------------------------------------------------------
    let t = Stopwatch::start();
    let cfg = base_cfg();
    let hist = figures::fig3(&mut rt, &cfg)?;
    println!("[Fig 3] regenerated in {:.1}s\n", t.elapsed_s());

    // ---- Fig 4 ---------------------------------------------------------
    let t = Stopwatch::start();
    figures::fig4(&mut rt, &cfg)?;
    println!("[Fig 4] regenerated in {:.1}s\n", t.elapsed_s());

    // ---- Table 1 -------------------------------------------------------
    let t = Stopwatch::start();
    let rows = coordinator::compare_schemes(
        &mut rt,
        &cfg,
        &["qedps", "na", "courbariaux", "gupta88", "fixed13", "float"],
    )?;
    coordinator::print_compare_table(&rows);
    println!("[Table 1] regenerated in {:.1}s\n", t.elapsed_s());

    // ---- Eq. 1 vs Eq. 2 ------------------------------------------------
    let t = Stopwatch::start();
    figures::rounding_ab(&mut rt, &cfg)?;
    println!("[Eq 1/2] A/B in {:.1}s\n", t.elapsed_s());

    // ---- §6 hardware speedup -------------------------------------------
    let speedup = figures::history_speedup(&rt, &cfg.model, &hist)?;
    println!("[§6] measured-trajectory flexible-MAC speedup: {speedup:.2}x\n");

    // ---- aggregation ablation ------------------------------------------
    println!("[ablation] stat aggregation across sites:");
    for agg in ["mean", "max", "last"] {
        let mut c = base_cfg();
        c.iters = 150;
        c.agg = qedps::policy::AggMode::from_str(agg).unwrap();
        let h = run_experiment(&mut rt, &c)?;
        let s = h.summary();
        println!("  agg={agg:<5} acc={:.4} bits(w/a/g)={:.1}/{:.1}/{:.1}",
                 s.final_test_acc, s.mean_weight_bits, s.mean_act_bits,
                 s.mean_grad_bits);
    }

    println!("\nbench_tables total: {:.1}s", total.elapsed_s());
    Ok(())
}
