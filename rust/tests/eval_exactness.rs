//! Exact evaluation on non-multiple test sets: a deterministic mock scorer
//! drives [`EvalBatcher`] batches through [`EvalAccum`], pinning the
//! masking contract the engine relies on — wrapped tail padding never
//! leaks into the totals, and the result is bit-identical across batch
//! sizes.  The artifact-backed tests at the bottom pin the same contract
//! for the engine's cached eval set vs the legacy per-batch refill path.

use qedps::data::{synth, Dataset, EvalBatcher, IMG_PIXELS};
use qedps::trainer::EvalAccum;

/// Deterministic per-example score: loss from the pixel payload, correctness
/// from the label parity.  Any pad entry that sneaks into the sums shifts
/// the result detectably.
fn score(x: &[f32], y: i32) -> (f32, f32) {
    let loss = x.iter().sum::<f32>() / IMG_PIXELS as f32 + 0.1 * y as f32;
    let correct = if y % 2 == 0 { 1.0 } else { 0.0 };
    (loss, correct)
}

/// Run the full set through [`EvalAccum`] at the given batch size, exactly
/// as the engine's per-example path does: score every slot, sum only the
/// first `valid`.
fn eval_at_batch(ds: &Dataset, batch: usize) -> (f32, f32) {
    let mut e = EvalBatcher::new(ds, batch);
    let mut x = vec![0.0f32; batch * IMG_PIXELS];
    let mut y = vec![0i32; batch];
    let mut acc = EvalAccum::new();
    while let Some(valid) = e.next_into(&mut x, &mut y) {
        let mut loss_vec = Vec::with_capacity(batch);
        let mut correct_vec = Vec::with_capacity(batch);
        for b in 0..batch {
            let (l, c) = score(&x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS], y[b]);
            loss_vec.push(l);
            correct_vec.push(c);
        }
        acc.add_examples(&loss_vec[..valid], &correct_vec[..valid]);
    }
    acc.finish()
}

#[test]
fn non_multiple_set_is_bit_identical_across_batch_sizes() {
    // 25 examples, batch 10: the third batch holds 5 valid + 5 wrapped pads
    let ds = synth::generate(25, 11);
    let (l1, a1) = eval_at_batch(&ds, 1);
    let (l10, a10) = eval_at_batch(&ds, 10);
    assert_eq!(l1.to_bits(), l10.to_bits(), "loss {l1} vs {l10}");
    assert_eq!(a1.to_bits(), a10.to_bits(), "acc {a1} vs {a10}");
    // and an awkward batch size that never divides anything
    let (l7, a7) = eval_at_batch(&ds, 7);
    assert_eq!(l1.to_bits(), l7.to_bits());
    assert_eq!(a1.to_bits(), a7.to_bits());
}

#[test]
fn unmasked_padding_contaminates_the_tail() {
    // The pre-fix failure mode: summing the *whole* tail batch (pads
    // included) and rescaling by valid/batch is not the true mean — the
    // wrapped entries re-count the head of the set.
    let ds = synth::generate(25, 11);
    let (exact_loss, _) = eval_at_batch(&ds, 1);

    let batch = 10;
    let mut e = EvalBatcher::new(&ds, batch);
    let mut x = vec![0.0f32; batch * IMG_PIXELS];
    let mut y = vec![0i32; batch];
    let mut acc = EvalAccum::new();
    while let Some(valid) = e.next_into(&mut x, &mut y) {
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        for b in 0..batch {
            let (l, c) = score(&x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS], y[b]);
            loss_sum += l;
            correct += c;
        }
        acc.add_batch_sums(loss_sum, correct, valid, batch);
    }
    let (approx_loss, _) = acc.finish();
    assert!(
        (approx_loss - exact_loss).abs() > 1e-6,
        "rescaled tail ({approx_loss}) should differ from exact ({exact_loss}) \
         on this set — if not, the contrast test lost its teeth"
    );
}

/// Batch the set once (the engine's `EvalSet` strategy: freeze every
/// batch's x/y/valid up front) and replay the frozen batches through the
/// scorer, instead of re-pulling from the batcher each pass.
fn eval_precomputed(ds: &Dataset, batch: usize, passes: usize) -> Vec<(f32, f32)> {
    let mut e = EvalBatcher::new(ds, batch);
    let mut x = vec![0.0f32; batch * IMG_PIXELS];
    let mut y = vec![0i32; batch];
    let mut frozen: Vec<(Vec<f32>, Vec<i32>, usize)> = Vec::with_capacity(e.num_batches());
    while let Some(valid) = e.next_into(&mut x, &mut y) {
        frozen.push((x.clone(), y.clone(), valid));
    }
    (0..passes)
        .map(|_| {
            let mut acc = EvalAccum::new();
            for (fx, fy, valid) in &frozen {
                let mut loss_vec = Vec::with_capacity(batch);
                let mut correct_vec = Vec::with_capacity(batch);
                for b in 0..batch {
                    let (l, c) = score(&fx[b * IMG_PIXELS..(b + 1) * IMG_PIXELS], fy[b]);
                    loss_vec.push(l);
                    correct_vec.push(c);
                }
                acc.add_examples(&loss_vec[..*valid], &correct_vec[..*valid]);
            }
            acc.finish()
        })
        .collect()
}

#[test]
fn precomputed_batches_match_streaming_refill_bit_for_bit() {
    // 25 examples at batches 10 and 7 (both leave a wrapped tail): freezing
    // the batches once and replaying them must equal re-batching every
    // pass, on every pass, at every batch size.
    let ds = synth::generate(25, 11);
    for batch in [1, 7, 10] {
        let streaming = eval_at_batch(&ds, batch);
        for (pass, &(l, a)) in eval_precomputed(&ds, batch, 3).iter().enumerate() {
            assert_eq!(
                l.to_bits(),
                streaming.0.to_bits(),
                "batch {batch} pass {pass}: loss {l} vs {}",
                streaming.0
            );
            assert_eq!(
                a.to_bits(),
                streaming.1.to_bits(),
                "batch {batch} pass {pass}: acc {a} vs {}",
                streaming.1
            );
        }
    }
}

#[test]
fn multiple_sized_set_needs_no_masking() {
    // when batch | n the legacy rescale is a no-op and both paths agree
    let ds = synth::generate(30, 12);
    let (exact_l, exact_a) = eval_at_batch(&ds, 10);
    let mut e = EvalBatcher::new(&ds, 10);
    let mut x = vec![0.0f32; 10 * IMG_PIXELS];
    let mut y = vec![0i32; 10];
    let mut acc = EvalAccum::new();
    while let Some(valid) = e.next_into(&mut x, &mut y) {
        assert_eq!(valid, 10);
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        for b in 0..10 {
            let (l, c) = score(&x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS], y[b]);
            loss_sum += l;
            correct += c;
        }
        acc.add_batch_sums(loss_sum, correct, valid, 10);
    }
    let (l, a) = acc.finish();
    assert_eq!(l.to_bits(), exact_l.to_bits());
    assert_eq!(a.to_bits(), exact_a.to_bits());
}

/// The engine's cached eval set must score a non-multiple test set
/// bit-identically to the legacy per-batch refill path, stay stable across
/// repeated passes, and build the set exactly once.
#[test]
fn engine_eval_set_matches_refill_path_bit_for_bit() {
    let mut rt = qedps::runtime::Runtime::create().unwrap();
    let mut cfg = qedps::config::ExperimentConfig::default();
    cfg.model = "mlp".into();
    assert!(cfg.eval_set, "the cached eval set is the default");
    let test = synth::generate(333, 13);

    let mut cached = qedps::trainer::Trainer::new(&mut rt, cfg.clone()).unwrap();
    let builds0 = qedps::telemetry::counter("eval.set_builds");
    let first = cached.evaluate(&test).unwrap();
    assert_eq!(
        qedps::telemetry::counter("eval.set_builds"),
        builds0 + 1,
        "first evaluate builds the set once"
    );
    let second = cached.evaluate(&test).unwrap();
    let third = cached.evaluate(&test).unwrap();
    assert_eq!(
        qedps::telemetry::counter("eval.set_builds"),
        builds0 + 1,
        "later passes reuse the cached set"
    );
    for (l, a) in [second, third] {
        assert_eq!(first.0.to_bits(), l.to_bits(), "loss drifted across passes");
        assert_eq!(first.1.to_bits(), a.to_bits(), "acc drifted across passes");
    }

    let mut refill_cfg = cfg.clone();
    refill_cfg.eval_set = false;
    let mut refill = qedps::trainer::Trainer::new(&mut rt, refill_cfg).unwrap();
    let (ll, la) = refill.evaluate(&test).unwrap();
    assert_eq!(first.0.to_bits(), ll.to_bits(), "loss: {} vs {ll}", first.0);
    assert_eq!(first.1.to_bits(), la.to_bits(), "acc: {} vs {la}", first.1);
}

/// Swapping datasets between `evaluate()` calls must rebuild the cached
/// set (fingerprint staleness) and still score each set correctly.
#[test]
fn engine_eval_set_rebuilds_when_the_dataset_changes() {
    let mut rt = qedps::runtime::Runtime::create().unwrap();
    let mut cfg = qedps::config::ExperimentConfig::default();
    cfg.model = "mlp".into();
    let set_a = synth::generate(333, 13);
    let set_b = synth::generate(207, 14);

    let mut t = qedps::trainer::Trainer::new(&mut rt, cfg.clone()).unwrap();
    let builds0 = qedps::telemetry::counter("eval.set_builds");
    let a_first = t.evaluate(&set_a).unwrap();
    let b_swapped = t.evaluate(&set_b).unwrap();
    let a_again = t.evaluate(&set_a).unwrap();
    assert_eq!(
        qedps::telemetry::counter("eval.set_builds"),
        builds0 + 3,
        "each dataset swap rebuilds the set"
    );
    assert_eq!(a_first.0.to_bits(), a_again.0.to_bits());
    assert_eq!(a_first.1.to_bits(), a_again.1.to_bits());

    // a fresh trainer that only ever saw set B must agree with the
    // swapped-in evaluation of set B above
    let mut fresh = qedps::trainer::Trainer::new(&mut rt, cfg).unwrap();
    let b_fresh = fresh.evaluate(&set_b).unwrap();
    assert_eq!(b_swapped.0.to_bits(), b_fresh.0.to_bits());
    assert_eq!(b_swapped.1.to_bits(), b_fresh.1.to_bits());
}
