//! Exact evaluation on non-multiple test sets, without artifacts: a
//! deterministic mock scorer drives [`EvalBatcher`] batches through
//! [`EvalAccum`], pinning the masking contract the engine relies on —
//! wrapped tail padding never leaks into the totals, and the result is
//! bit-identical across batch sizes.

use qedps::data::{synth, Dataset, EvalBatcher, IMG_PIXELS};
use qedps::trainer::EvalAccum;

/// Deterministic per-example score: loss from the pixel payload, correctness
/// from the label parity.  Any pad entry that sneaks into the sums shifts
/// the result detectably.
fn score(x: &[f32], y: i32) -> (f32, f32) {
    let loss = x.iter().sum::<f32>() / IMG_PIXELS as f32 + 0.1 * y as f32;
    let correct = if y % 2 == 0 { 1.0 } else { 0.0 };
    (loss, correct)
}

/// Run the full set through [`EvalAccum`] at the given batch size, exactly
/// as the engine's per-example path does: score every slot, sum only the
/// first `valid`.
fn eval_at_batch(ds: &Dataset, batch: usize) -> (f32, f32) {
    let mut e = EvalBatcher::new(ds, batch);
    let mut x = vec![0.0f32; batch * IMG_PIXELS];
    let mut y = vec![0i32; batch];
    let mut acc = EvalAccum::new();
    while let Some(valid) = e.next_into(&mut x, &mut y) {
        let mut loss_vec = Vec::with_capacity(batch);
        let mut correct_vec = Vec::with_capacity(batch);
        for b in 0..batch {
            let (l, c) = score(&x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS], y[b]);
            loss_vec.push(l);
            correct_vec.push(c);
        }
        acc.add_examples(&loss_vec[..valid], &correct_vec[..valid]);
    }
    acc.finish()
}

#[test]
fn non_multiple_set_is_bit_identical_across_batch_sizes() {
    // 25 examples, batch 10: the third batch holds 5 valid + 5 wrapped pads
    let ds = synth::generate(25, 11);
    let (l1, a1) = eval_at_batch(&ds, 1);
    let (l10, a10) = eval_at_batch(&ds, 10);
    assert_eq!(l1.to_bits(), l10.to_bits(), "loss {l1} vs {l10}");
    assert_eq!(a1.to_bits(), a10.to_bits(), "acc {a1} vs {a10}");
    // and an awkward batch size that never divides anything
    let (l7, a7) = eval_at_batch(&ds, 7);
    assert_eq!(l1.to_bits(), l7.to_bits());
    assert_eq!(a1.to_bits(), a7.to_bits());
}

#[test]
fn unmasked_padding_contaminates_the_tail() {
    // The pre-fix failure mode: summing the *whole* tail batch (pads
    // included) and rescaling by valid/batch is not the true mean — the
    // wrapped entries re-count the head of the set.
    let ds = synth::generate(25, 11);
    let (exact_loss, _) = eval_at_batch(&ds, 1);

    let batch = 10;
    let mut e = EvalBatcher::new(&ds, batch);
    let mut x = vec![0.0f32; batch * IMG_PIXELS];
    let mut y = vec![0i32; batch];
    let mut acc = EvalAccum::new();
    while let Some(valid) = e.next_into(&mut x, &mut y) {
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        for b in 0..batch {
            let (l, c) = score(&x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS], y[b]);
            loss_sum += l;
            correct += c;
        }
        acc.add_batch_sums(loss_sum, correct, valid, batch);
    }
    let (approx_loss, _) = acc.finish();
    assert!(
        (approx_loss - exact_loss).abs() > 1e-6,
        "rescaled tail ({approx_loss}) should differ from exact ({exact_loss}) \
         on this set — if not, the contrast test lost its teeth"
    );
}

#[test]
fn multiple_sized_set_needs_no_masking() {
    // when batch | n the legacy rescale is a no-op and both paths agree
    let ds = synth::generate(30, 12);
    let (exact_l, exact_a) = eval_at_batch(&ds, 10);
    let mut e = EvalBatcher::new(&ds, 10);
    let mut x = vec![0.0f32; 10 * IMG_PIXELS];
    let mut y = vec![0i32; 10];
    let mut acc = EvalAccum::new();
    while let Some(valid) = e.next_into(&mut x, &mut y) {
        assert_eq!(valid, 10);
        let mut loss_sum = 0.0f32;
        let mut correct = 0.0f32;
        for b in 0..10 {
            let (l, c) = score(&x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS], y[b]);
            loss_sum += l;
            correct += c;
        }
        acc.add_batch_sums(loss_sum, correct, valid, 10);
    }
    let (l, a) = acc.finish();
    assert_eq!(l.to_bits(), exact_l.to_bits());
    assert_eq!(a.to_bits(), exact_a.to_bits());
}
