//! Runtime integration: every AOT artifact loads, compiles, executes, and
//! produces self-consistent outputs.

use qedps::policy::PrecState;
use qedps::runtime::{literal_f32, literal_i32, Runtime};
use qedps::util::rng::Pcg32;
use xla::Literal;

fn runtime() -> Runtime {
    Runtime::create().expect("runtime (run `make artifacts` first)")
}

#[test]
fn manifest_covers_all_models_and_kinds() {
    let rt = runtime();
    for model in ["mlp", "lenet"] {
        for suffix in ["train", "train_nearest", "train_float", "eval", "eval_float"] {
            let name = format!("{model}_{suffix}");
            assert!(rt.manifest.modules.contains_key(&name), "missing {name}");
        }
        assert!(rt.manifest.models.contains_key(model));
    }
}

#[test]
fn params_load_with_manifest_shapes() {
    let rt = runtime();
    for model in ["mlp", "lenet"] {
        let params = rt.load_params(model).unwrap();
        let meta = rt.manifest.model(model).unwrap();
        assert_eq!(params.len(), meta.params.len());
        let total: usize = params.iter().map(|p| p.element_count()).sum();
        assert_eq!(total, meta.param_count());
    }
    // LeNet parameter count is the classic 431,080
    assert_eq!(runtime().manifest.model("lenet").unwrap().param_count(), 431_080);
}

/// One full train step through the artifact: shapes in = shapes out, loss
/// finite, stats in range, weights actually change.
#[test]
fn mlp_train_step_executes() {
    let mut rt = runtime();
    let exe = rt.load("mlp_train").unwrap();
    let params = rt.load_params("mlp").unwrap();
    let mom = rt.zeros_like_params("mlp").unwrap();
    let spec = exe.spec.clone();
    let batch = rt.manifest.train_batch;

    let mut rng = Pcg32::seeded(1);
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(10) as i32).collect();
    let prec = PrecState::default_paper();

    let x_l = literal_f32(&x, &[batch, 784]).unwrap();
    let y_l = literal_i32(&y, &[batch]).unwrap();
    let lr = Literal::scalar(0.01f32);
    let seed = Literal::scalar(1.0f32);
    let prec_l = literal_f32(&prec.to_vec(), &[6]).unwrap();

    let mut inputs: Vec<&Literal> = params.iter().chain(mom.iter()).collect();
    inputs.push(&x_l);
    inputs.push(&y_l);
    inputs.push(&lr);
    inputs.push(&seed);
    inputs.push(&prec_l);

    let outs = exe.run(&inputs).unwrap();
    assert_eq!(outs.len(), spec.outputs.len());
    let n_p = params.len();
    // new params have original shapes and differ from the old ones
    let w0_new = outs[0].to_vec::<f32>().unwrap();
    let w0_old = params[0].to_vec::<f32>().unwrap();
    assert_eq!(w0_new.len(), w0_old.len());
    assert_ne!(w0_new, w0_old, "weights did not move");
    let loss = outs[2 * n_p].get_first_element::<f32>().unwrap();
    let acc = outs[2 * n_p + 1].get_first_element::<f32>().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
    let evec = outs[2 * n_p + 2].to_vec::<f32>().unwrap();
    let rvec = outs[2 * n_p + 3].to_vec::<f32>().unwrap();
    assert_eq!(evec.len(), spec.sites.len());
    assert!(evec.iter().all(|v| v.is_finite() && *v >= 0.0));
    assert!(rvec.iter().all(|v| (0.0..=1.0).contains(v)));
}

/// Determinism: identical inputs (incl. seed) => identical outputs.
#[test]
fn train_step_deterministic() {
    let mut rt = runtime();
    let exe = rt.load("mlp_train").unwrap();
    let params = rt.load_params("mlp").unwrap();
    let mom = rt.zeros_like_params("mlp").unwrap();
    let batch = rt.manifest.train_batch;
    let mut rng = Pcg32::seeded(9);
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(10) as i32).collect();

    let run = |rt_exe: &qedps::runtime::Executable| -> Vec<f32> {
        let x_l = literal_f32(&x, &[batch, 784]).unwrap();
        let y_l = literal_i32(&y, &[batch]).unwrap();
        let lr = Literal::scalar(0.05f32);
        let seed = Literal::scalar(7.0f32);
        let prec_l =
            literal_f32(&PrecState::default_paper().to_vec(), &[6]).unwrap();
        let mut inputs: Vec<&Literal> = params.iter().chain(mom.iter()).collect();
        inputs.push(&x_l);
        inputs.push(&y_l);
        inputs.push(&lr);
        inputs.push(&seed);
        inputs.push(&prec_l);
        let outs = rt_exe.run(&inputs).unwrap();
        outs[0].to_vec::<f32>().unwrap()
    };
    assert_eq!(run(&exe), run(&exe));
}

/// The float artifact must be insensitive to the prec input.
#[test]
fn float_step_ignores_prec() {
    let mut rt = runtime();
    let exe = rt.load("mlp_train_float").unwrap();
    let params = rt.load_params("mlp").unwrap();
    let mom = rt.zeros_like_params("mlp").unwrap();
    let batch = rt.manifest.train_batch;
    let mut rng = Pcg32::seeded(3);
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(10) as i32).collect();

    let run = |prec: [f32; 6]| -> Vec<f32> {
        let x_l = literal_f32(&x, &[batch, 784]).unwrap();
        let y_l = literal_i32(&y, &[batch]).unwrap();
        let lr = Literal::scalar(0.05f32);
        let seed = Literal::scalar(7.0f32);
        let prec_l = literal_f32(&prec, &[6]).unwrap();
        let mut inputs: Vec<&Literal> = params.iter().chain(mom.iter()).collect();
        inputs.push(&x_l);
        inputs.push(&y_l);
        inputs.push(&lr);
        inputs.push(&seed);
        inputs.push(&prec_l);
        let outs = exe.run(&inputs).unwrap();
        outs[0].to_vec::<f32>().unwrap()
    };
    assert_eq!(run([2.0, 14.0, 4.0, 12.0, 2.0, 20.0]), run([1.0, 1.0, 1.0, 1.0, 1.0, 1.0]));
}

/// Wrong input arity must be rejected before reaching PJRT.
#[test]
fn arity_validated() {
    let mut rt = runtime();
    let exe = rt.load("quantize_sr_4096").unwrap();
    let x = literal_f32(&vec![0.0; 4096], &[4096]).unwrap();
    assert!(exe.run(&[&x]).is_err());
}

/// qmatmul artifact: quantize+matmul against the Rust mirror + f64 dot.
#[test]
fn qmatmul_artifact_matches_mirror() {
    use qedps::fixedpoint::{quantize_slice, Format, RoundMode};
    let mut rt = runtime();
    let exe = rt.load("qmatmul_256").unwrap();
    let mut rng = Pcg32::seeded(5);
    let a: Vec<f32> = (0..256 * 256).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..256 * 256).map(|_| rng.normal() as f32 * 0.1).collect();
    let (il, fl, seed) = (4, 10, 21);

    let inputs = [
        literal_f32(&a, &[256, 256]).unwrap(),
        literal_f32(&b, &[256, 256]).unwrap(),
        literal_f32(&[il as f32, fl as f32, il as f32, fl as f32], &[4]).unwrap(),
        Literal::scalar(seed),
    ];
    let outs = exe.run(&inputs).unwrap();
    let c = outs[0].to_vec::<f32>().unwrap();

    // mirror: quantize with the same global-flat-index streams, f64 matmul
    let (qa, _) = quantize_slice(&a, Format::new(il, fl), seed, RoundMode::Stochastic);
    let (qb, _) =
        quantize_slice(&b, Format::new(il, fl), seed + 0x1234567, RoundMode::Stochastic);
    // check a handful of entries exactly enough for f32 accumulation noise
    for &(i, j) in &[(0usize, 0usize), (1, 7), (100, 200), (255, 255), (37, 0)] {
        let want: f64 = (0..256)
            .map(|k| qa[i * 256 + k] as f64 * qb[k * 256 + j] as f64)
            .sum();
        let got = c[i * 256 + j] as f64;
        assert!(
            (got - want).abs() < 1e-2 * (1.0 + want.abs()),
            "c[{i},{j}] = {got}, mirror {want}"
        );
    }
}

trait PrecExt {
    fn default_paper() -> PrecState;
}

impl PrecExt for PrecState {
    fn default_paper() -> PrecState {
        qedps::policy::PolicyOptions::default().init
    }
}
