//! End-to-end training integration: the full L3 loop (data → step → stats →
//! policy → precision) over the real AOT artifacts, plus checkpointing.

use qedps::config::ExperimentConfig;
use qedps::runtime::Runtime;
use qedps::trainer::{checkpoint, run_experiment, Trainer};

fn quick_cfg(scheme: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.scheme = scheme.into();
    cfg.iters = 60;
    cfg.train_n = 1000;
    cfg.test_n = 200;
    cfg.eval_every = 30;
    cfg.log_every = 5;
    cfg.out_dir = std::env::temp_dir()
        .join("qedps_itest")
        .to_string_lossy()
        .into_owned();
    cfg
}

#[test]
fn qedps_short_run_learns_and_scales() {
    let mut rt = Runtime::create().unwrap();
    let hist = run_experiment(&mut rt, &quick_cfg("qedps")).unwrap();
    let s = hist.summary();
    assert!(s.final_test_acc > 0.5, "acc {}", s.final_test_acc);
    assert!(s.final_train_loss < 1.5, "loss {}", s.final_train_loss);
    // the controller must actually have moved the precision
    let bits: Vec<i32> = hist.train.iter().map(|r| r.prec.weights.bits()).collect();
    assert!(bits.iter().any(|&b| b != bits[0]), "precision never moved");
    // history recorded on schedule
    assert!(hist.train.len() >= 12);
    assert!(!hist.eval.is_empty());
}

#[test]
fn float_short_run_learns() {
    let mut rt = Runtime::create().unwrap();
    let hist = run_experiment(&mut rt, &quick_cfg("float")).unwrap();
    let s = hist.summary();
    assert!(s.final_test_acc > 0.5, "acc {}", s.final_test_acc);
    // float runs report constant 32-bit words
    assert!(hist.train.iter().all(|r| r.prec.weights.bits() == 32));
}

#[test]
fn courbariaux_keeps_width_constant_through_training() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("courbariaux");
    cfg.iters = 40;
    let hist = run_experiment(&mut rt, &cfg).unwrap();
    for r in &hist.train {
        assert_eq!(r.prec.weights.bits(), 16);
        assert_eq!(r.prec.acts.bits(), 16);
    }
}

#[test]
fn nearest_artifact_runs_for_na_policy() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("na");
    cfg.iters = 30;
    let hist = run_experiment(&mut rt, &cfg).unwrap();
    assert!(hist.summary().final_train_loss.is_finite());
}

#[test]
fn deterministic_given_config() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps");
    cfg.iters = 20;
    cfg.eval_every = 0;
    let a = run_experiment(&mut rt, &cfg).unwrap();
    let b = run_experiment(&mut rt, &cfg).unwrap();
    let la: Vec<f32> = a.train.iter().map(|r| r.loss).collect();
    let lb: Vec<f32> = b.train.iter().map(|r| r.loss).collect();
    assert_eq!(la, lb, "same config+seed must reproduce the loss curve");
}

#[test]
fn stat_aggregation_modes_differ() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps");
    cfg.iters = 25;
    cfg.eval_every = 0;
    cfg.agg = qedps::policy::AggMode::Mean;
    let mean_hist = run_experiment(&mut rt, &cfg).unwrap();
    cfg.agg = qedps::policy::AggMode::Max;
    let max_hist = run_experiment(&mut rt, &cfg).unwrap();
    // Max aggregation sees larger E, so it should hold FL at least as high.
    let mean_fl: f64 = mean_hist.train.iter().map(|r| r.prec.acts.fl as f64).sum::<f64>()
        / mean_hist.train.len() as f64;
    let max_fl: f64 = max_hist.train.iter().map(|r| r.prec.acts.fl as f64).sum::<f64>()
        / max_hist.train.len() as f64;
    assert!(max_fl >= mean_fl - 0.5, "max {max_fl} vs mean {mean_fl}");
}

/// The step hot path must run entirely on pre-pinned input literals:
/// after the engine is constructed, steady-state stepping performs zero
/// `Literal` builds (refills via `copy_raw_from` don't count — or allocate).
#[test]
fn step_hot_path_builds_no_literals() {
    let mut rt = Runtime::create().unwrap();
    let cfg = quick_cfg("qedps");
    let (train, _, _) = qedps::data::load_default(cfg.train_n, cfg.test_n);
    let mut t = Trainer::new(&mut rt, cfg.clone()).unwrap();
    let mut b = qedps::data::Batcher::new(&train, t.train_batch_size(), cfg.seed);
    for i in 0..3 {
        t.fill_batch(&mut b);
        t.step(i).unwrap();
    }
    let before = qedps::runtime::literal_builds();
    for i in 3..13 {
        t.fill_batch(&mut b);
        t.step(i).unwrap();
    }
    assert_eq!(
        qedps::runtime::literal_builds(),
        before,
        "steady-state Trainer::step must not construct literals"
    );
}

/// Device-resident state invariant: after warmup, steady-state stepping
/// performs zero host<->device parameter/momentum transfers — the train
/// executable consumes last step's output buffers directly.
#[test]
fn step_hot_path_is_transfer_free_when_device_resident() {
    let mut rt = Runtime::create().unwrap();
    let cfg = quick_cfg("qedps");
    let (train, _, _) = qedps::data::load_default(cfg.train_n, cfg.test_n);
    let mut t = Trainer::new(&mut rt, cfg.clone()).unwrap();
    if !t.device_resident() {
        // platform fell back to host literals; the invariant doesn't apply
        return;
    }
    let mut b = qedps::data::Batcher::new(&train, t.train_batch_size(), cfg.seed);
    for i in 0..3 {
        t.fill_batch(&mut b);
        t.step(i).unwrap();
    }
    let before = qedps::runtime::host_transfers();
    for i in 3..13 {
        t.fill_batch(&mut b);
        t.step(i).unwrap();
    }
    assert_eq!(
        qedps::runtime::host_transfers(),
        before,
        "steady-state device-resident step must not copy state across host<->device"
    );
}

/// The host-literal fallback path (`device_params = false`) must be a pure
/// perf downgrade: the loss trajectory is identical to the device-resident
/// path, and every step pays host<->device state traffic.
#[test]
fn fallback_literal_path_matches_device_resident_losses() {
    let mut rt = Runtime::create().unwrap();
    let cfg = quick_cfg("qedps");
    let (train, _, _) = qedps::data::load_default(cfg.train_n, cfg.test_n);

    let run = |rt: &mut Runtime, device: bool| -> Vec<u32> {
        let mut c = cfg.clone();
        c.device_params = device;
        let mut t = Trainer::new(rt, c).unwrap();
        let mut b = qedps::data::Batcher::new(&train, t.train_batch_size(), cfg.seed);
        (0..8)
            .map(|i| {
                t.fill_batch(&mut b);
                t.step(i).unwrap().loss.to_bits()
            })
            .collect()
    };
    let resident = run(&mut rt, true);
    let fallback = run(&mut rt, false);
    assert_eq!(
        resident, fallback,
        "host-literal fallback must reproduce the device-resident loss curve"
    );
}

/// Non-multiple test sets evaluate exactly: a 25-example set at eval-batch
/// granularity must score bit-identically to summing the same examples in
/// smaller pieces (the per-example artifacts mask wrapped tail entries).
#[test]
fn eval_non_multiple_test_set_is_exact() {
    let mut rt = Runtime::create().unwrap();
    let cfg = quick_cfg("qedps");
    let mut t = Trainer::new(&mut rt, cfg).unwrap();
    if !t.eval_exact() {
        // legacy scalar eval artifacts can only rescale the tail batch
        return;
    }
    // 25 examples with a batch size that doesn't divide it: the tail batch
    // wraps, and pad entries must not leak into the totals
    let full = qedps::data::synth::generate(25, 11);
    let (l_full, a_full) = t.evaluate(&full).unwrap();
    // reference: the same 25 examples split as 10+10+5 via dataset slices
    let mut loss_sum = 0f64;
    let mut correct_sum = 0f64;
    for (lo, hi) in [(0usize, 10usize), (10, 20), (20, 25)] {
        let part = full.slice(lo, hi);
        let (l, a) = t.evaluate(&part).unwrap();
        let n = (hi - lo) as f64;
        loss_sum += l as f64 * n;
        correct_sum += a as f64 * n;
    }
    let l_ref = (loss_sum / 25.0) as f32;
    let a_ref = (correct_sum / 25.0) as f32;
    assert!(
        (l_full - l_ref).abs() < 1e-5,
        "loss {l_full} vs split reference {l_ref}"
    );
    assert!(
        (a_full - a_ref).abs() < 1e-6,
        "acc {a_full} vs split reference {a_ref}"
    );
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let mut rt = Runtime::create().unwrap();
    let cfg = quick_cfg("qedps");
    let dir = std::env::temp_dir().join("qedps_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_string_lossy().into_owned();

    // run 10 steps, checkpoint, run 5 more recording losses
    let (train, _, _) = qedps::data::load_default(cfg.train_n, cfg.test_n);
    let mut t1 = Trainer::new(&mut rt, cfg.clone()).unwrap();
    let mut b1 = qedps::data::Batcher::new(&train, t1.train_batch_size(), cfg.seed);
    for i in 0..10 {
        t1.fill_batch(&mut b1);
        t1.step(i).unwrap();
    }
    checkpoint::save(&dir_s, &t1, 9).unwrap();
    let mut losses_direct = Vec::new();
    for i in 10..15 {
        t1.fill_batch(&mut b1);
        losses_direct.push(t1.step(i).unwrap().loss);
    }

    // fresh trainer, restore, replay the same batches
    let mut t2 = Trainer::new(&mut rt, cfg.clone()).unwrap();
    let next = checkpoint::load_latest(&dir_s, &mut t2).unwrap();
    assert_eq!(next, 10);
    let mut b2 = qedps::data::Batcher::new(&train, t2.train_batch_size(), cfg.seed);
    let mut skip_x = vec![0.0; t2.train_batch_size() * 784];
    let mut skip_y = vec![0; t2.train_batch_size()];
    for _ in 0..10 {
        b2.next_into(&mut skip_x, &mut skip_y);
    }
    let mut losses_resumed = Vec::new();
    for i in 10..15 {
        t2.fill_batch(&mut b2);
        losses_resumed.push(t2.step(i).unwrap().loss);
    }
    assert_eq!(losses_direct, losses_resumed);
}

/// The §5 divergence demonstration must be *observable*: fixed 13-bit LeNet
/// training degrades relative to qedps on the same budget.  (Kept on MLP
/// with a tiny budget for test speed; the full LeNet figure is
/// `repro figures --fig 4`.)
#[test]
fn fixed13_worse_than_qedps_short_horizon() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps");
    cfg.iters = 80;
    cfg.eval_every = 0;
    let q = run_experiment(&mut rt, &cfg).unwrap();
    cfg.scheme = "fixed13".into();
    let f = run_experiment(&mut rt, &cfg).unwrap();
    let ql = q.summary().final_train_loss;
    let fl = f.summary().final_train_loss;
    assert!(
        !fl.is_finite() || fl > ql * 0.8,
        "fixed13 ({fl}) should not beat qedps ({ql}) meaningfully"
    );
}
