//! End-to-end resilience: fault injection → watchdog trip → rollback with
//! precision escalation → deterministic replay → clean completion; plus
//! torn-checkpoint resume and the graceful-abort path.

use qedps::config::ExperimentConfig;
use qedps::runtime::Runtime;
use qedps::trainer::{checkpoint, run_experiment, Trainer};

fn quick_cfg(scheme: &str, tag: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.scheme = scheme.into();
    cfg.iters = 40;
    cfg.train_n = 1000;
    cfg.test_n = 200;
    cfg.eval_every = 0;
    cfg.log_every = 1;
    cfg.out_dir = std::env::temp_dir()
        .join(format!("qedps_rtest_{tag}"))
        .to_string_lossy()
        .into_owned();
    cfg
}

fn fresh_dir(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("qedps_rtest_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir.to_string_lossy().into_owned()
}

/// The acceptance scenario: a bit-flipped weight tensor plus a forced NaN
/// loss mid-run.  The watchdog must trip, roll back to the last good
/// checkpoint, escalate precision, and the run must still complete with a
/// finite loss and the whole recovery trail in the summary.
#[test]
fn injected_faults_roll_back_escalate_and_complete() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps", "faults_out");
    cfg.checkpoint_dir = Some(fresh_dir("faults_ckpt"));
    cfg.checkpoint_every = 5;
    cfg.faults = vec!["bitflip@8:weight".into(), "nan@12".into()];
    cfg.fault_seed = 7;
    cfg.recovery_backoff = 2; // short grace so both faults can trip in 40 iters
    let hist = run_experiment(&mut rt, &cfg).unwrap();

    let s = hist.summary();
    assert_eq!(s.status.as_str(), "ok", "run must complete cleanly");
    assert!(s.final_train_loss.is_finite(), "loss {}", s.final_train_loss);
    assert!(s.recoveries >= 1, "at least one rollback expected");

    let kinds: Vec<&str> = hist.recovery.iter().map(|e| e.kind.as_str()).collect();
    assert!(kinds.contains(&"fault_bitflip"), "trail {kinds:?}");
    assert!(kinds.contains(&"fault_loss"), "trail {kinds:?}");
    // every rollback names the iteration it rewound to
    let rollbacks: Vec<_> =
        hist.recovery.iter().filter(|e| e.rollback_to.is_some()).collect();
    assert!(!rollbacks.is_empty());
    for e in &rollbacks {
        assert!(e.rollback_to.unwrap() <= e.iter, "{e:?}");
    }
    // poisoned records must not survive the rewind
    assert!(hist.train.iter().all(|r| r.loss.is_finite()));

    // the trail is exported in the summary JSON
    let j = hist.summary_json();
    assert_eq!(j.get("status").as_str(), Some("ok"));
    assert!(j.get("recoveries").as_f64().unwrap() >= 1.0);
    assert!(j.get("recovery_events").at(0).get("kind").as_str().is_some());
}

/// Surgical single-fault case with a fully deterministic rollback target:
/// checkpoints land at iters 5 and 10, the NaN fires at 12, so the run must
/// rewind to exactly iter 11 (= checkpoint 10 + 1) and escalate precision.
#[test]
fn forced_nan_rewinds_to_last_checkpoint() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps", "nan_out");
    cfg.iters = 30;
    cfg.checkpoint_dir = Some(fresh_dir("nan_ckpt"));
    cfg.checkpoint_every = 5;
    cfg.faults = vec!["nan@12".into()];
    let hist = run_experiment(&mut rt, &cfg).unwrap();

    let trips: Vec<_> = hist
        .recovery
        .iter()
        .filter(|e| e.kind == "non_finite_loss")
        .collect();
    assert_eq!(trips.len(), 1, "trail {:?}", hist.recovery);
    assert_eq!(trips[0].iter, 12);
    assert_eq!(trips[0].rollback_to, Some(11));
    assert_eq!(hist.summary().recoveries, 1);
    assert_eq!(hist.summary().status.as_str(), "ok");

    // escalation must be visible in the recorded precision: the first
    // record after the rewind is at least as wide as the pre-trip one
    let before = hist.train.iter().find(|r| r.iter == 10).expect("iter 10");
    let after = hist.train.iter().find(|r| r.iter == 11).expect("iter 11");
    assert!(
        after.prec.mean_bits() + 1.0 > before.prec.mean_bits(),
        "escalated {} vs {}",
        after.prec.mean_bits(),
        before.prec.mean_bits()
    );
}

/// A torn (partial) checkpoint directory — state.json missing — and a
/// leftover `.tmp` staging dir must both be skipped; resume lands on the
/// newest checkpoint that validates.
#[test]
fn resume_skips_torn_and_staged_checkpoints() {
    let mut rt = Runtime::create().unwrap();
    let cfg = quick_cfg("qedps", "torn_out");
    let dir = fresh_dir("torn_ckpt");

    let (train, _, _) = qedps::data::load_default(cfg.train_n, cfg.test_n);
    let mut t1 = Trainer::new(&mut rt, cfg.clone()).unwrap();
    let mut b1 = qedps::data::Batcher::new(&train, t1.train_batch_size(), cfg.seed);
    for i in 0..10 {
        t1.fill_batch(&mut b1);
        t1.step(i).unwrap();
        if i == 4 || i == 9 {
            checkpoint::save(&dir, &t1, i).unwrap();
        }
    }

    let root = std::path::Path::new(&dir);
    // tear the newest checkpoint: crash between tensor writes and state.json
    std::fs::remove_file(root.join("state-9").join("state.json")).unwrap();
    // and simulate a crash mid-stage: an abandoned temp dir
    std::fs::create_dir_all(root.join("state-999.tmp")).unwrap();

    let mut t2 = Trainer::new(&mut rt, cfg.clone()).unwrap();
    let next = checkpoint::load_latest(&dir, &mut t2).unwrap();
    assert_eq!(next, 5, "must fall back to the intact state-4");
}

/// A checkpoint whose tensor bytes were corrupted after writing must fail
/// checksum validation and be skipped on resume.
#[test]
fn resume_skips_checksum_mismatch() {
    let mut rt = Runtime::create().unwrap();
    let cfg = quick_cfg("qedps", "sum_out");
    let dir = fresh_dir("sum_ckpt");

    let (train, _, _) = qedps::data::load_default(cfg.train_n, cfg.test_n);
    let mut t1 = Trainer::new(&mut rt, cfg.clone()).unwrap();
    let mut b1 = qedps::data::Batcher::new(&train, t1.train_batch_size(), cfg.seed);
    for i in 0..8 {
        t1.fill_batch(&mut b1);
        t1.step(i).unwrap();
        if i == 3 || i == 7 {
            checkpoint::save(&dir, &t1, i).unwrap();
        }
    }

    // flip one byte of a tensor payload in the newest checkpoint
    let victim = std::path::Path::new(&dir).join("state-7").join("p_0.npy");
    let mut bytes = std::fs::read(&victim).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(&victim, bytes).unwrap();

    let mut t2 = Trainer::new(&mut rt, cfg.clone()).unwrap();
    let next = checkpoint::load_latest(&dir, &mut t2).unwrap();
    assert_eq!(next, 4, "corrupt state-7 must be skipped for state-3");
}

/// `resume = true` continues a finished segment instead of restarting, and
/// the resume itself is recorded as informational (not a recovery).
#[test]
fn resume_flag_continues_where_the_run_left_off() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps", "resume_out");
    cfg.iters = 10;
    cfg.checkpoint_dir = Some(fresh_dir("resume_ckpt"));
    cfg.checkpoint_every = 5;
    run_experiment(&mut rt, &cfg).unwrap();

    cfg.iters = 20;
    cfg.resume = true;
    let hist = run_experiment(&mut rt, &cfg).unwrap();
    assert!(hist.recovery.iter().any(|e| e.kind == "resume"));
    assert_eq!(hist.summary().recoveries, 0, "a resume is not a recovery");
    // segment 1 checkpointed its last iter (9), so segment 2 starts at 10
    let first = hist.train.iter().map(|r| r.iter).min().unwrap();
    assert_eq!(first, 10);
    assert_eq!(hist.summary().status.as_str(), "ok");
}

/// Exhausting the retry budget aborts gracefully: the error names the
/// report, and the report carries the recovery trail.
#[test]
fn exhausted_retries_abort_with_failure_report() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps", "abort_out");
    let _ = std::fs::remove_dir_all(&cfg.out_dir);
    cfg.iters = 10;
    cfg.faults = vec!["nan@3".into()];
    cfg.max_recoveries = 0;
    let err = run_experiment(&mut rt, &cfg).unwrap_err();
    assert!(format!("{err:#}").contains("aborted"), "{err:#}");

    let report_path = std::path::Path::new(&cfg.out_dir).join("failure_report.json");
    let j = qedps::util::json::Json::parse(
        &std::fs::read_to_string(&report_path).unwrap(),
    )
    .unwrap();
    assert_eq!(j.get("status").as_str(), Some("aborted"));
    assert_eq!(j.get("scheme").as_str(), Some("qedps"));
    let events = j.get("recovery_events");
    assert!(events.at(0).get("kind").as_str().is_some(), "trail recorded");
}

/// Transient read failures are retried away: a single injected read-fail
/// must not kill the run.
#[test]
fn transient_read_failure_is_retried() {
    let mut rt = Runtime::create().unwrap();
    let mut cfg = quick_cfg("qedps", "readfail_out");
    cfg.iters = 5;
    cfg.faults = vec!["read-fail".into()];
    let hist = run_experiment(&mut rt, &cfg).unwrap();
    assert_eq!(hist.summary().status.as_str(), "ok");
}

/// `read-fail` guards more than the dataset read: artifact compilation and
/// parameter loads consult the same injector.  Armed directly on the
/// runtime so the failures land on `Runtime::load` / `load_params` instead
/// of being absorbed by the session's dataset retry (which always runs
/// first and would drain the budget).
#[test]
fn read_failures_cover_artifact_and_param_loads() {
    use qedps::resilience::FaultInjector;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn armed_budget(rt: &mut Runtime, n: u32) -> Rc<RefCell<FaultInjector>> {
        let inj = Rc::new(RefCell::new(
            FaultInjector::from_specs(&[format!("read-fail:{n}")], 1).unwrap(),
        ));
        rt.arm_faults(inj.clone());
        inj
    }

    // two injected failures hit the first guarded artifact read; the
    // 3-attempt retry absorbs both and compilation still succeeds
    let mut rt = Runtime::create().unwrap();
    let inj = armed_budget(&mut rt, 2);
    let cfg = quick_cfg("qedps", "artload_out");
    Trainer::new(&mut rt, cfg).unwrap();
    assert!(inj.borrow().is_empty(), "artifact load must drain the budget");

    // params specifically: re-arm and call the guarded load directly
    let inj = armed_budget(&mut rt, 2);
    let params = rt.load_params("mlp").unwrap();
    assert!(!params.is_empty());
    assert!(inj.borrow().is_empty(), "load_params must drain the budget");
    rt.disarm_faults();
}
