//! Cross-module property tests over the Rust substrates (in-repo harness —
//! no proptest offline): quantizer invariants, policy state machines,
//! JSON/TOML round-trips, data pipeline, MAC-sim consistency.

use qedps::fixedpoint::{quantize_slice, Format, RoundMode};
use qedps::macsim::{self, MacUnit};
use qedps::policy::{
    make_policy, ClassStats, Feedback, PolicyOptions, PrecState,
};
use qedps::testutil::check;
use qedps::util::json::Json;

// ---------------------------------------------------------------------------
// Quantizer properties
// ---------------------------------------------------------------------------

#[test]
fn prop_quantizer_output_on_grid_and_in_range() {
    check("on_grid_in_range", 0xF00D, 200, |g| {
        let il = g.i32_in(1, 12);
        let fl = g.i32_in(0, 12); // il+fl <= 24: grid exactly representable
        let n = g.usize_in(1, 400);
        let scale = g.f32_in(0.01, 50.0);
        let x = g.vec_f32(n, scale);
        let seed = g.i32_in(0, 1 << 30);
        let fmt = Format::new(il, fl);
        let mode = *g.choice(&[RoundMode::Stochastic, RoundMode::Nearest]);
        let (q, _) = quantize_slice(&x, fmt, seed, mode);
        let step = fmt.step();
        for (i, &v) in q.iter().enumerate() {
            if v < fmt.min_val() || v > fmt.max_val() {
                return Err(format!("[{i}] {v} outside {fmt}"));
            }
            let scaled = (v / step) as f64;
            if (scaled - scaled.round()).abs() > 1e-6 {
                return Err(format!("[{i}] {v} off grid {fmt}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_error_bounded_by_step() {
    check("err_le_step", 0xBEEF, 200, |g| {
        let il = g.i32_in(2, 10);
        let fl = g.i32_in(0, 14);
        let fmt = Format::new(il, fl);
        let x = g.vec_f32(64, fmt.max_val() * 0.4);
        let seed = g.i32_in(0, 1 << 30);
        let (q, _) = quantize_slice(&x, fmt, seed, RoundMode::Stochastic);
        for (&xi, &qi) in x.iter().zip(&q) {
            if fmt.contains(xi) && (qi - xi).abs() > fmt.step() + 1e-6 {
                return Err(format!("x={xi} q={qi} step={}", fmt.step()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_idempotent() {
    check("idempotent", 0xCAFE, 120, |g| {
        let fmt = Format::new(g.i32_in(2, 10), g.i32_in(0, 12));
        let x = g.vec_f32(128, 2.0);
        let mode = *g.choice(&[RoundMode::Stochastic, RoundMode::Nearest]);
        let (q1, _) = quantize_slice(&x, fmt, g.i32_in(0, 99999), mode);
        let (q2, _) = quantize_slice(&q1, fmt, g.i32_in(0, 99999), mode);
        if q1 != q2 {
            return Err("Q(Q(x)) != Q(x)".into());
        }
        Ok(())
    });
}

#[test]
fn prop_widening_format_never_increases_error() {
    check("monotone_fl", 0xAB1E, 100, |g| {
        let il = g.i32_in(3, 8);
        let fl = g.i32_in(0, 10);
        let x = g.vec_f32(256, 1.0);
        let seed = g.i32_in(0, 99999);
        let (_, s1) = quantize_slice(&x, Format::new(il, fl), seed, RoundMode::Nearest);
        let (_, s2) =
            quantize_slice(&x, Format::new(il, fl + 2), seed, RoundMode::Nearest);
        if s2.e > s1.e + 1e-7 {
            return Err(format!("E rose: {} -> {} (fl {fl}->{})", s1.e, s2.e, fl + 2));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Policy properties
// ---------------------------------------------------------------------------

fn fb(e: f32, r: f32, loss: f32, iter: u64) -> Feedback {
    let s = ClassStats { e, r };
    Feedback { iter, loss, weights: s, acts: s, grads: s }
}

#[test]
fn prop_all_policies_stay_in_legal_range() {
    let schemes = ["qedps", "na", "courbariaux", "fixed", "fixed13", "gupta88",
                   "schedule"];
    check("policies_in_range", 0x9999, 150, |g| {
        let scheme = *g.choice(&schemes);
        let mut p = make_policy(scheme, &PolicyOptions::default()).unwrap();
        let mut st = p.init();
        for iter in 0..40 {
            let f = fb(
                g.f32_in(0.0, 0.01),
                g.f32_in(0.0, 0.01),
                g.f32_in(0.01, 3.0),
                iter,
            );
            st = p.update(st, &f);
            for fmt in [st.weights, st.acts, st.grads] {
                if fmt.il < 1 || fmt.il > 24 || fmt.fl < 0 || fmt.fl > 24 {
                    return Err(format!("{scheme}: illegal {fmt}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_qedps_monotone_response() {
    // Strictly larger-signal feedback never yields a smaller next format.
    check("qedps_monotone", 0x1234, 150, |g| {
        let mut p1 = make_policy("qedps", &PolicyOptions::default()).unwrap();
        let mut p2 = make_policy("qedps", &PolicyOptions::default()).unwrap();
        let st = PrecState::uniform(Format::new(g.i32_in(2, 20), g.i32_in(2, 20)));
        let e = g.f32_in(0.0, 0.01);
        let r = g.f32_in(0.0, 0.01);
        let lo = p1.update(st, &fb(e, r, 1.0, 0));
        let hi = p2.update(st, &fb(e * 10.0 + 0.001, r * 10.0 + 0.001, 1.0, 0));
        if hi.weights.fl < lo.weights.fl || hi.weights.il < lo.weights.il {
            return Err(format!("{lo:?} vs {hi:?}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// JSON fuzz round-trip
// ---------------------------------------------------------------------------

#[test]
fn prop_json_roundtrip_random_trees() {
    fn gen_value(g: &mut qedps::testutil::Gen, depth: usize) -> Json {
        let kind = if depth > 3 { g.usize_in(0, 3) } else { g.usize_in(0, 5) };
        match kind {
            0 => Json::Null,
            1 => Json::Bool(g.usize_in(0, 1) == 1),
            2 => Json::Num((g.f32_in(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
            3 => {
                let n = g.usize_in(0, 8);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            *g.choice(&['a', 'ß', '"', '\\', '\n', '😀', 'z', ' '])
                        })
                        .collect(),
                )
            }
            4 => {
                let n = g.usize_in(0, 4);
                Json::Arr((0..n).map(|_| gen_value(g, depth + 1)).collect())
            }
            _ => {
                let n = g.usize_in(0, 4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_value(g, depth + 1)))
                        .collect(),
                )
            }
        }
    }
    check("json_roundtrip", 0x7777, 300, |g| {
        let v = gen_value(g, 0);
        let s = v.to_string();
        let parsed = Json::parse(&s).map_err(|e| format!("{e} in {s}"))?;
        if parsed != v {
            return Err(format!("{s} reparsed differently"));
        }
        let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
        if pretty != v {
            return Err("pretty roundtrip differs".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Data pipeline + macsim consistency
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_preserves_image_label_pairing() {
    use qedps::data::{synth, Batcher, IMG_PIXELS};
    let ds = synth::generate(60, 17);
    check("batcher_pairing", 0x5150, 30, |g| {
        let bsz = g.usize_in(1, 16);
        let mut b = Batcher::new(&ds, bsz, g.usize_in(0, 1000) as u64);
        let mut x = vec![0.0; bsz * IMG_PIXELS];
        let mut y = vec![0; bsz];
        for _ in 0..5 {
            b.next_into(&mut x, &mut y);
            for k in 0..bsz {
                let img = &x[k * IMG_PIXELS..(k + 1) * IMG_PIXELS];
                // find the dataset index with identical pixels
                let found = (0..ds.n).find(|&i| ds.image(i) == img);
                match found {
                    None => return Err("batch image not from dataset".into()),
                    Some(i) => {
                        if ds.labels[i] as i32 != y[k] {
                            return Err(format!("label mismatch at {i}"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_macsim_cycles_monotone_in_bits() {
    let layers = macsim::layer_costs(&[("w", vec![100usize, 50])], (28, 28), 8);
    let unit = MacUnit::default();
    check("macsim_monotone", 0x6006, 100, |g| {
        let b1 = g.i32_in(2, 30);
        let b2 = g.i32_in(2, 30);
        let (lo, hi) = if b1 <= b2 { (b1, b2) } else { (b2, b1) };
        let c_lo = macsim::iteration_cycles(
            &unit,
            &layers,
            &PrecState::uniform(Format::new(lo / 2 + 1, lo - lo / 2 - 1)),
        );
        let c_hi = macsim::iteration_cycles(
            &unit,
            &layers,
            &PrecState::uniform(Format::new(hi / 2 + 1, hi - hi / 2 - 1)),
        );
        if c_lo > c_hi {
            return Err(format!("bits {lo}<{hi} but cycles {c_lo}>{c_hi}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Config fuzz: every generated config either applies cleanly or errors
// without panicking.
// ---------------------------------------------------------------------------

#[test]
fn prop_config_set_never_panics() {
    let keys = ["scheme", "iters", "lr0", "e_max", "agg", "init_acts",
                "bogus_key", "model"];
    check("config_set", 0x3333, 200, |g| {
        let key = *g.choice(&keys);
        let val = match g.usize_in(0, 3) {
            0 => format!("{}", g.i32_in(-5, 5000)),
            1 => format!("{:.4}", g.f32_in(-1.0, 1.0)),
            2 => "\"qedps\"".to_string(),
            _ => format!("[{}, {}]", g.i32_in(0, 30), g.i32_in(0, 30)),
        };
        let mut cfg = qedps::config::ExperimentConfig::default();
        let _ = cfg.apply_set(&format!("{key}={val}")); // must not panic
        Ok(())
    });
}
