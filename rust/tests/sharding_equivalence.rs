//! Determinism across dispatch modes: a sweep must produce byte-identical
//! output whether it runs serially, across worker threads (`--jobs`), or
//! split into subprocess shards (`--shard i/n`) and merged.  These tests
//! pin the acceptance criterion for the sharded coordinator.

use qedps::config::ExperimentConfig;
use qedps::coordinator::{self, compare_rows_json, figures, CompareRow, ShardOpts};
use qedps::runtime::Runtime;
use qedps::trainer::run_experiment;

fn sweep_cfg(sub: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "mlp".into();
    cfg.iters = 30;
    cfg.train_n = 600;
    cfg.test_n = 200;
    cfg.eval_every = 15;
    cfg.log_every = 0;
    cfg.out_dir = std::env::temp_dir()
        .join(format!("qedps_shard_test_{sub}"))
        .to_string_lossy()
        .into_owned();
    cfg
}

fn rows_bytes(rows: &[CompareRow]) -> String {
    // wall-clock timing columns are machine load, not run content — zero
    // them so byte-identity only pins the deterministic fields
    let mut rows = rows.to_vec();
    for r in &mut rows {
        r.mean_step_ms = 0.0;
        r.p95_step_ms = 0.0;
    }
    compare_rows_json(&rows).to_string_pretty()
}

/// Drop the `None` slots a shard filter leaves behind.
fn done(rows: Vec<Option<CompareRow>>) -> Vec<CompareRow> {
    rows.into_iter().flatten().collect()
}

#[test]
fn compare_jobs2_matches_serial_bytes() {
    let base = sweep_cfg("jobs2");
    let schemes = ["qedps", "float"];
    let serial = done(
        coordinator::compare_schemes_sharded(
            &base,
            &schemes,
            &ShardOpts { jobs: 1, shard: None },
        )
        .unwrap(),
    );
    let threaded = done(
        coordinator::compare_schemes_sharded(
            &base,
            &schemes,
            &ShardOpts { jobs: 2, shard: None },
        )
        .unwrap(),
    );
    assert_eq!(serial.len(), schemes.len());
    assert_eq!(
        rows_bytes(&serial),
        rows_bytes(&threaded),
        "--jobs 2 must emit the same table bytes as a serial sweep"
    );
}

#[test]
fn two_shard_union_matches_serial() {
    let base = sweep_cfg("union");
    let schemes = ["qedps", "float", "fixed13"];
    let serial = done(
        coordinator::compare_schemes_sharded(
            &base,
            &schemes,
            &ShardOpts { jobs: 1, shard: None },
        )
        .unwrap(),
    );

    // shard 1/2 owns indices {0, 2}, shard 2/2 owns {1}; each shard's
    // output round-trips through the on-disk slice format, and merging
    // the slices must rebuild the serial table byte-for-byte — the exact
    // pipeline behind `repro compare --shard i/n` + `repro compare merge`
    let mut slices = Vec::new();
    for spec in ["1/2", "2/2"] {
        let shard = coordinator::Shard::parse(spec).unwrap();
        let opts = ShardOpts { jobs: 1, shard: Some(shard) };
        let rows = coordinator::compare_schemes_sharded(&base, &schemes, &opts).unwrap();
        let text = coordinator::compare_shard_json(&rows, &shard).to_string_pretty();
        slices.push(coordinator::parse_shard_slice(&text).unwrap());
    }
    let merged = coordinator::merge_shard_slices(&slices).unwrap();

    let names: Vec<&str> = merged.iter().map(|r| r.scheme.as_str()).collect();
    assert_eq!(names, schemes, "merged rows must follow scheme order");
    assert_eq!(rows_bytes(&serial), rows_bytes(&merged));
}

#[test]
fn telemetry_counters_merge_identically_across_jobs() {
    // a threaded sweep runs its workers on fresh threads (fresh telemetry
    // registries); the sharder folds their snapshots back into this thread,
    // so the merged counter totals must equal a serial sweep's exactly
    let base = sweep_cfg("telemetry");
    let schemes = ["qedps", "float"];

    let before = qedps::telemetry::snapshot();
    coordinator::compare_schemes_sharded(
        &base,
        &schemes,
        &ShardOpts { jobs: 1, shard: None },
    )
    .unwrap();
    let serial = qedps::telemetry::snapshot().diff(&before);

    let before = qedps::telemetry::snapshot();
    coordinator::compare_schemes_sharded(
        &base,
        &schemes,
        &ShardOpts { jobs: 2, shard: None },
    )
    .unwrap();
    let threaded = qedps::telemetry::snapshot().diff(&before);

    assert!(!serial.is_empty(), "a sweep must record telemetry");
    assert!(
        serial.counter("engine.steps") >= base.iters * schemes.len() as u64,
        "every run's steps must be counted"
    );
    // the process-wide dataset cache's hit/miss split depends on which
    // test warmed the key first, not on dispatch mode — exclude it from
    // the equality and pin it separately in dataset_cache_hits_across_jobs
    let strip_cache = |s: &qedps::telemetry::Snapshot| -> std::collections::BTreeMap<String, u64> {
        s.counters()
            .iter()
            .filter(|(k, _)| !k.starts_with("data.cache_"))
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    };
    assert_eq!(
        strip_cache(&serial),
        strip_cache(&threaded),
        "--jobs 2 must merge to the same counter totals as a serial sweep"
    );
    for (name, h) in serial.spans() {
        assert_eq!(
            Some(h.count()),
            threaded.spans().get(name).map(|t| t.count()),
            "span '{name}' count must survive the worker merge"
        );
    }
}

#[test]
fn dataset_cache_hits_across_jobs() {
    // sizes unique to this test, so no other test in the process warms the
    // cache key: a --jobs 2 sweep over three schemes must parse the data
    // exactly once and serve every other run from the shared cache
    let mut base = sweep_cfg("datacache");
    base.train_n = 601;
    base.test_n = 201;
    let schemes = ["qedps", "float", "fixed13"];

    let before = qedps::telemetry::snapshot();
    coordinator::compare_schemes_sharded(
        &base,
        &schemes,
        &ShardOpts { jobs: 2, shard: None },
    )
    .unwrap();
    let delta = qedps::telemetry::snapshot().diff(&before);

    assert_eq!(
        delta.counter("data.cache_misses"),
        1,
        "one dataset parse per process for this key"
    );
    assert_eq!(
        delta.counter("data.cache_hits"),
        schemes.len() as u64 - 1,
        "every other run shares the cached datasets"
    );
}

#[test]
fn rounding_ab_sharded_matches_serial() {
    let mut cfg = sweep_cfg("roundab");
    cfg.iters = 20;
    cfg.eval_every = 10;
    let mut rt = Runtime::create().unwrap();
    let serial = figures::rounding_ab(&mut rt, &cfg).unwrap();
    drop(rt);
    let sharded =
        figures::rounding_ab_sharded(&cfg, &ShardOpts { jobs: 2, shard: None }).unwrap();
    assert_eq!(serial.len(), sharded.len());
    for ((ta, sa), (tb, sb)) in serial.iter().zip(sharded.iter()) {
        assert_eq!(ta, tb, "arm order must match the lineup");
        assert_eq!(sa.final_test_acc.to_bits(), sb.final_test_acc.to_bits());
        assert_eq!(sa.best_test_acc.to_bits(), sb.best_test_acc.to_bits());
        assert_eq!(sa.final_train_loss.to_bits(), sb.final_train_loss.to_bits());
    }
}

#[test]
fn history_bits_identical_across_dispatch() {
    let cfg = sweep_cfg("bits");
    let mut rt = Runtime::create().unwrap();
    let direct = run_experiment(&mut rt, &cfg).unwrap();

    // one-spec sweep through the sharder: fresh runtime, worker thread path
    let sharded = coordinator::sharder::run_sharded(
        &[()],
        &ShardOpts { jobs: 1, shard: None },
        |rt, _idx, _spec| run_experiment(rt, &cfg),
    )
    .unwrap()
    .into_iter()
    .flatten()
    .next()
    .expect("single spec must yield a history");

    assert_eq!(direct.train.len(), sharded.train.len());
    for (a, b) in direct.train.iter().zip(sharded.train.iter()) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss bits @ {}", a.iter);
        assert_eq!(a.acc.to_bits(), b.acc.to_bits(), "acc bits @ {}", a.iter);
        assert_eq!(a.prec.to_vec(), b.prec.to_vec(), "precision @ {}", a.iter);
    }
    for (a, b) in direct.eval.iter().zip(sharded.eval.iter()) {
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
    }
}
