//! **The cross-language spec check**: the Rust software quantizer
//! (`fixedpoint::quantize`) and the AOT-compiled Pallas kernel
//! (`artifacts/quantize_*.hlo.txt`) must agree **bit-for-bit** on quantized
//! values, and to float tolerance on the (E, R) statistics.
//!
//! If this passes, the three implementations of the quantizer spec — the
//! Pallas kernel, the pure-jnp oracle (checked by pytest), and the Rust
//! mirror — are all the same function.

use qedps::fixedpoint::{quantize_slice, Format, RoundMode};
use qedps::runtime::{literal_f32, Runtime};
use qedps::util::rng::Pcg32;
use xla::Literal;

fn randvec(n: usize, scale: f32, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

fn run_artifact(
    rt: &mut Runtime,
    module: &str,
    x: &[f32],
    il: i32,
    fl: i32,
    seed: i32,
) -> (Vec<f32>, f32, f32) {
    let exe = rt.load(module).expect("load artifact");
    let n = exe.spec.inputs[0].elems();
    assert_eq!(x.len(), n, "artifact {module} wants {n} elems");
    let inputs = [
        literal_f32(x, &[n]).unwrap(),
        Literal::scalar(il),
        Literal::scalar(fl),
        Literal::scalar(seed),
    ];
    let outs = exe.run(&inputs).expect("execute");
    (
        outs[0].to_vec::<f32>().unwrap(),
        outs[1].get_first_element::<f32>().unwrap(),
        outs[2].get_first_element::<f32>().unwrap(),
    )
}

fn check_parity(module: &str, mode: RoundMode, n: usize, scale: f32) {
    let mut rt = Runtime::create().expect("runtime (run `make artifacts`)");
    let x = randvec(n, scale, 0xA11CE);
    for (il, fl, seed) in [
        (4, 8, 1),
        (8, 8, 42),
        (2, 14, 7),
        (16, 14, 12345),
        (1, 0, 3),
        (4, 9, 999),
        (24, 0, 5),
    ] {
        let (q_hlo, e_hlo, r_hlo) = run_artifact(&mut rt, module, &x, il, fl, seed);
        let (q_sw, stats) = quantize_slice(&x, Format::new(il, fl), seed, mode);
        // Values: BIT-exact.
        let mismatches: Vec<usize> = q_hlo
            .iter()
            .zip(&q_sw)
            .enumerate()
            .filter(|(_, (a, b))| a.to_bits() != b.to_bits())
            .map(|(i, _)| i)
            .collect();
        assert!(
            mismatches.is_empty(),
            "{module} <{il},{fl}> seed {seed}: {} mismatches, first at {}: hlo={} sw={}",
            mismatches.len(),
            mismatches[0],
            q_hlo[mismatches[0]],
            q_sw[mismatches[0]]
        );
        // Stats: float tolerance (different summation order).
        assert!(
            (e_hlo - stats.e).abs() <= 1e-5 * (1.0 + stats.e.abs()),
            "{module} <{il},{fl}>: E {e_hlo} vs {}",
            stats.e
        );
        assert!(
            (r_hlo - stats.r).abs() <= 1e-6,
            "{module} <{il},{fl}>: R {r_hlo} vs {}",
            stats.r
        );
    }
}

#[test]
fn stochastic_parity_single_block() {
    check_parity("quantize_sr_4096", RoundMode::Stochastic, 4096, 4.0);
}

#[test]
fn stochastic_parity_multi_block() {
    // 131072 = 2 kernel blocks: exercises the grid + per-block stat partials
    check_parity("quantize_sr_131072", RoundMode::Stochastic, 131072, 4.0);
}

#[test]
fn nearest_parity() {
    check_parity("quantize_rn_4096", RoundMode::Nearest, 4096, 4.0);
}

#[test]
fn parity_with_saturation() {
    // large scale so the clip path + R stat are exercised hard
    check_parity("quantize_sr_4096", RoundMode::Stochastic, 4096, 64.0);
}

#[test]
fn parity_on_adversarial_values() {
    let mut rt = Runtime::create().unwrap();
    let mut x = vec![0.0f32; 4096];
    let specials = [
        0.0,
        -0.0,
        1.0,
        -1.0,
        0.5,
        0.25,
        0.3,
        -0.3,
        127.996,
        -128.0,
        1e-10,
        -1e-10,
        9.40234375,
        2407.0 / 256.0,
        31.99609375,
        1e6,
        -1e6,
        f32::MIN_POSITIVE,
    ];
    x[..specials.len()].copy_from_slice(&specials);
    let mut rng = Pcg32::seeded(77);
    for v in x.iter_mut().skip(specials.len()) {
        // mixture of magnitudes across many orders
        let exp = -20 + rng.below(41) as i32;
        *v = (rng.normal() as f32) * (2.0f32).powi(exp);
    }
    let (q_hlo, _, _) = run_artifact(&mut rt, "quantize_sr_4096", &x, 6, 10, 31337);
    let (q_sw, _) = quantize_slice(&x, Format::new(6, 10), 31337, RoundMode::Stochastic);
    for (i, (a, b)) in q_hlo.iter().zip(&q_sw).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: x={} hlo={a} sw={b}", x[i]);
    }
}
