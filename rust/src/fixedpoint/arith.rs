//! Integer fixed-point arithmetic — the operations the paper's flexible
//! MAC unit performs in hardware.
//!
//! A `<ILa,FLa> x <ILw,FLw>` multiply produces an exact product with
//! `FLa+FLw` fractional bits; a dot product accumulates such products in a
//! wide (i64 here, 48-bit in Na & Mukhopadhyay's unit) register and rounds
//! once on writeback.  [`crate::macsim`] uses these semantics to validate
//! its cycle model against real arithmetic, and the tests demonstrate the
//! claim the emulation relies on: *f32 emulation of the quantized network
//! computes the same numbers the fixed-point hardware would*, as long as
//! word lengths stay within the f32 mantissa.

use super::format::Format;

/// A value held in integer fixed-point representation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fixed {
    pub bits: i64,
    pub fmt: Format,
}

impl Fixed {
    /// Encode an f32 that is already on the `fmt` grid (debug-asserted).
    pub fn encode(x: f32, fmt: Format) -> Self {
        let bits = (x as f64 * (1u64 << fmt.fl) as f64).round() as i64;
        debug_assert!(
            ((x as f64) - bits as f64 / (1u64 << fmt.fl) as f64).abs() < 1e-9,
            "{x} is not on the {fmt} grid"
        );
        Self { bits, fmt }
    }

    pub fn value(&self) -> f32 {
        (self.bits as f64 / (1u64 << self.fmt.fl) as f64) as f32
    }

    /// Saturating addition of two same-format values.
    pub fn sat_add(self, other: Fixed) -> Fixed {
        assert_eq!(self.fmt, other.fmt);
        let (lo, hi) = self.fmt.bit_bounds();
        Fixed { bits: (self.bits + other.bits).clamp(lo, hi), fmt: self.fmt }
    }

    /// Exact multiply: output format is `<ILa+ILw, FLa+FLw>` (no rounding —
    /// this is what the MAC's wide product register holds).
    pub fn mul_exact(self, other: Fixed) -> Fixed {
        Fixed {
            bits: self.bits * other.bits,
            fmt: Format::new(self.fmt.il + other.fmt.il, self.fmt.fl + other.fmt.fl),
        }
    }
}

/// Wide MAC accumulator: exact products summed in i64, rounded once on
/// writeback to the output format (round-to-nearest-even on the grid).
#[derive(Debug, Clone)]
pub struct MacAccumulator {
    acc: i64,
    frac_bits: i32,
}

impl MacAccumulator {
    pub fn new(fmt_a: Format, fmt_w: Format) -> Self {
        Self { acc: 0, frac_bits: fmt_a.fl + fmt_w.fl }
    }

    pub fn mac(&mut self, a: Fixed, w: Fixed) {
        debug_assert_eq!(a.fmt.fl + w.fmt.fl, self.frac_bits);
        self.acc += a.bits * w.bits;
    }

    /// Read back at full accumulator precision as f64 (exact).
    pub fn value(&self) -> f64 {
        self.acc as f64 / (1u64 << self.frac_bits) as f64
    }

    /// Round + saturate into `out` format (hardware writeback).
    pub fn writeback(&self, out: Format) -> Fixed {
        let shift = self.frac_bits - out.fl;
        let bits = if shift <= 0 {
            self.acc << (-shift)
        } else {
            // round half to even at the dropped-bit boundary
            let half = 1i64 << (shift - 1);
            let floor = self.acc >> shift;
            let rem = self.acc - (floor << shift);
            let up = rem > half || (rem == half && (floor & 1) == 1);
            floor + up as i64
        };
        let (lo, hi) = out.bit_bounds();
        Fixed { bits: bits.clamp(lo, hi), fmt: out }
    }
}

/// Exact fixed-point dot product via the wide accumulator.
pub fn fixed_dot(a: &[f32], w: &[f32], fmt_a: Format, fmt_w: Format) -> f64 {
    assert_eq!(a.len(), w.len());
    let mut acc = MacAccumulator::new(fmt_a, fmt_w);
    for (&x, &y) in a.iter().zip(w) {
        acc.mac(Fixed::encode(x, fmt_a), Fixed::encode(y, fmt_w));
    }
    acc.value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize::{quantize_slice, RoundMode};
    use crate::util::rng::Pcg32;

    #[test]
    fn encode_roundtrip() {
        let fmt = Format::new(4, 8);
        for b in -1024..1024 {
            let x = b as f32 / 256.0;
            assert_eq!(Fixed::encode(x, fmt).value(), x);
        }
    }

    #[test]
    fn sat_add_saturates() {
        let fmt = Format::new(4, 4); // range [-8, 8-1/16]
        let a = Fixed::encode(7.0, fmt);
        let b = Fixed::encode(5.0, fmt);
        assert_eq!(a.sat_add(b).value(), fmt.max_val());
        let c = Fixed::encode(-8.0, fmt);
        assert_eq!(c.sat_add(c).value(), fmt.min_val());
    }

    #[test]
    fn mul_exact_widens() {
        let fa = Format::new(4, 4);
        let fw = Format::new(2, 6);
        let p = Fixed::encode(1.5, fa).mul_exact(Fixed::encode(0.25, fw));
        assert_eq!(p.fmt, Format::new(6, 10));
        assert_eq!(p.value(), 0.375);
    }

    /// The core emulation-fidelity claim: an f32 dot product of quantized
    /// values equals the exact integer MAC, while word lengths fit f32.
    #[test]
    fn f32_emulation_matches_integer_mac() {
        let fmt_a = Format::new(4, 6);
        let fmt_w = Format::new(2, 8);
        let mut rng = Pcg32::seeded(9);
        let raw_a: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let raw_w: Vec<f32> = (0..256).map(|_| rng.normal() as f32 * 0.2).collect();
        let (qa, _) = quantize_slice(&raw_a, fmt_a, 1, RoundMode::Stochastic);
        let (qw, _) = quantize_slice(&raw_w, fmt_w, 2, RoundMode::Stochastic);

        let exact = fixed_dot(&qa, &qw, fmt_a, fmt_w);
        let f64dot: f64 = qa.iter().zip(&qw).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((exact - f64dot).abs() < 1e-9, "{exact} vs {f64dot}");
    }

    #[test]
    fn writeback_rounds_half_even() {
        let fmt_a = Format::new(4, 2);
        let fmt_w = Format::new(4, 2);
        let mut acc = MacAccumulator::new(fmt_a, fmt_w);
        // 0.25 * 0.5 = 0.125: exactly half a step of <4,2> (step 0.25)
        acc.mac(Fixed::encode(0.25, fmt_a), Fixed::encode(0.5, fmt_w));
        assert_eq!(acc.writeback(Format::new(4, 2)).value(), 0.0); // ties-to-even
        acc.mac(Fixed::encode(0.25, fmt_a), Fixed::encode(1.0, fmt_w));
        // 0.375 -> nearest grid 0.5 (0.375 is 1.5 steps; even -> wait: rounds
        // to 2 steps = 0.5? 1.5 is equidistant between 1 and 2; even is 2.)
        assert_eq!(acc.writeback(Format::new(4, 2)).value(), 0.5);
    }

    #[test]
    fn writeback_saturates() {
        let fmt = Format::new(2, 2);
        let mut acc = MacAccumulator::new(fmt, fmt);
        for _ in 0..100 {
            acc.mac(Fixed::encode(1.5, fmt), Fixed::encode(1.5, fmt));
        }
        assert_eq!(acc.writeback(fmt).value(), fmt.max_val());
    }
}
