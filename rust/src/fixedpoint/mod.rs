//! Software fixed-point substrate: the `<IL, FL>` format, a **bit-exact
//! mirror** of the L1 Pallas quantizer, and integer fixed-point arithmetic
//! (what the paper's flexible MAC unit executes).
//!
//! Three consumers:
//! * `rust/tests/quantize_parity.rs` — asserts this mirror and the AOT HLO
//!   artifact agree element-for-element (the cross-language spec check);
//! * [`crate::policy`] unit tests — drive controllers with software stats;
//! * [`crate::macsim`] — operand bit-widths and exact MAC semantics.

pub mod arith;
pub mod format;
pub mod quantize;

pub use format::{Format, FL_RANGE, IL_RANGE};
pub use quantize::{quantize_slice, quantize_slice_at, QuantStats, RoundMode};
