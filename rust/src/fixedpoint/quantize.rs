//! Bit-exact software mirror of the L1 Pallas quantizer.
//!
//! Every operation here reproduces `kernels/quantize.py::_quantize_block` in
//! f32, in the same order: scale by the exact power of two, floor, exact
//! residual, hash-noise comparison, clip, relative-error stat.  The parity
//! test executes the AOT `quantize_*.hlo.txt` artifacts and asserts the
//! quantized vectors agree **bit-for-bit** with this mirror.

use super::format::{exp2i, Format};
use crate::util::rng::uniform01;

pub const EPS: f32 = 1e-8;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Paper Eq. 2 (Gupta et al.): round up with probability = residual.
    Stochastic,
    /// Paper Eq. 1: round-to-nearest, half-up.
    Nearest,
}

/// Aggregate feedback statistics of one quantization pass.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct QuantStats {
    /// Mean relative quantization error — the paper's `E`.
    pub e: f32,
    /// Overflow (saturation) rate — the paper's `R`.
    pub r: f32,
}

/// Quantize one element given its rounding noise `u` (in `[0,1)`).
///
/// Returns `(q, overflowed)`.  `fmt` must already be clamped to the legal
/// range (the slice entrypoint does this).
#[inline]
pub fn quantize_val(x: f32, u: f32, fmt: Format, mode: RoundMode) -> (f32, bool) {
    let s = exp2i(fmt.fl);
    let inv_s = exp2i(-fmt.fl);
    let hi = exp2i(fmt.il - 1) - inv_s;
    let lo = -exp2i(fmt.il - 1);
    let xs = x * s;
    let f = xs.floor();
    let r = xs - f; // exact (Sterbenz)
    let up = match mode {
        RoundMode::Stochastic => r > u,
        RoundMode::Nearest => r >= u,
    };
    let y = (f + up as u32 as f32) * inv_s;
    let q = y.clamp(lo, hi);
    let ovf = x < lo || x > hi;
    (q, ovf)
}

/// Quantize a slice with the kernel's counter-hash noise stream.
///
/// `idx_base` is the global flat index of `x[0]` (the kernel numbers noise
/// by flat element position, so a sub-slice of a larger tensor quantizes
/// identically when given its true offset).
pub fn quantize_slice_at(
    x: &[f32],
    idx_base: u32,
    fmt: Format,
    seed: i32,
    mode: RoundMode,
    out: &mut Vec<f32>,
) -> QuantStats {
    let fmt = fmt.clamped();
    out.clear();
    out.reserve(x.len());
    // E is a ratio of means — sum|q-x| / (sum|x| + eps) — matching the
    // kernel (per-element relative error is dominated by near-zero entries).
    let mut esum = 0.0f64;
    let mut xsum = 0.0f64;
    let mut rsum = 0u64;
    for (i, &v) in x.iter().enumerate() {
        let u = match mode {
            RoundMode::Stochastic => {
                uniform01(idx_base.wrapping_add(i as u32), seed as u32)
            }
            RoundMode::Nearest => 0.5,
        };
        let (q, ovf) = quantize_val(v, u, fmt, mode);
        esum += (q - v).abs() as f64;
        xsum += v.abs() as f64;
        rsum += ovf as u64;
        out.push(q);
    }
    let n = x.len().max(1) as f64;
    QuantStats {
        e: (esum / (xsum + EPS as f64)) as f32,
        r: (rsum as f64 / n) as f32,
    }
}

/// Convenience wrapper allocating the output.
pub fn quantize_slice(
    x: &[f32],
    fmt: Format,
    seed: i32,
    mode: RoundMode,
) -> (Vec<f32>, QuantStats) {
    let mut out = Vec::new();
    let stats = quantize_slice_at(x, 0, fmt, seed, mode, &mut out);
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randvec(n: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    #[test]
    fn values_on_grid_and_in_range() {
        let fmt = Format::new(4, 6);
        let x = randvec(4096, 8.0, 1);
        let (q, stats) = quantize_slice(&x, fmt, 7, RoundMode::Stochastic);
        for &v in &q {
            assert!((v * 64.0).fract() == 0.0, "off grid: {v}");
            assert!(v >= fmt.min_val() && v <= fmt.max_val());
        }
        assert!(stats.r > 0.0); // scale 8 >> range 8 ⇒ saturation
    }

    #[test]
    fn nearest_is_round_half_up() {
        let fmt = Format::new(4, 2); // step 0.25
        let (q, _) = quantize_slice(&[0.124, 0.126, 0.125, -0.125], fmt, 0,
                                    RoundMode::Nearest);
        assert_eq!(q, vec![0.0, 0.25, 0.25, -0.0]);
    }

    #[test]
    fn stochastic_idempotent() {
        let fmt = Format::new(6, 8);
        let x = randvec(2048, 4.0, 2);
        let (q1, _) = quantize_slice(&x, fmt, 1, RoundMode::Stochastic);
        let (q2, s2) = quantize_slice(&q1, fmt, 99, RoundMode::Stochastic);
        assert_eq!(q1, q2);
        assert_eq!(s2.e, 0.0);
    }

    #[test]
    fn stochastic_unbiased() {
        // E[Q(0.3)] == 0.3 at step 1/16.
        let fmt = Format::new(4, 4);
        let mut acc = 0.0f64;
        let n = 40_000;
        for s in 0..n {
            let (q, _) = quantize_slice(&[0.3], fmt, s, RoundMode::Stochastic);
            acc += q[0] as f64;
        }
        let mean = acc / n as f64;
        assert!((mean - 0.3).abs() < 2e-3, "mean={mean}");
    }

    #[test]
    fn nearest_biased() {
        let fmt = Format::new(4, 4);
        let (q, _) = quantize_slice(&[0.3], fmt, 0, RoundMode::Nearest);
        assert_eq!(q[0], 0.3125);
    }

    #[test]
    fn error_monotone_in_fl() {
        let x = randvec(8192, 0.5, 3);
        let mut last = f32::INFINITY;
        for fl in [2, 6, 10, 14] {
            let (_, s) = quantize_slice(&x, Format::new(4, fl), 5,
                                        RoundMode::Stochastic);
            assert!(s.e < last, "fl={fl}: {} !< {last}", s.e);
            last = s.e;
        }
    }

    #[test]
    fn overflow_monotone_in_il() {
        let x = randvec(8192, 8.0, 4);
        let mut last = 2.0f32;
        for il in [1, 3, 5, 8] {
            let (_, s) = quantize_slice(&x, Format::new(il, 8), 5,
                                        RoundMode::Stochastic);
            assert!(s.r < last, "il={il}");
            last = s.r;
        }
    }

    #[test]
    fn offset_slices_compose() {
        // Quantizing [a | b] == quantizing a at 0 ++ b at a.len().
        let x = randvec(1000, 2.0, 5);
        let fmt = Format::new(5, 7);
        let (whole, _) = quantize_slice(&x, fmt, 11, RoundMode::Stochastic);
        let mut front = Vec::new();
        let mut back = Vec::new();
        quantize_slice_at(&x[..400], 0, fmt, 11, RoundMode::Stochastic, &mut front);
        quantize_slice_at(&x[400..], 400, fmt, 11, RoundMode::Stochastic, &mut back);
        front.extend_from_slice(&back);
        assert_eq!(whole, front);
    }

    #[test]
    fn large_magnitude_no_residual_spill() {
        // Regression for the floor(x*s + u) f32 bug: values whose scaled
        // magnitude is large must still round within one step.
        let fmt = Format::new(6, 8);
        let x = [9.40234375f32, 2407.0 / 256.0, 31.99609375];
        for seed in 0..200 {
            let (q, _) = quantize_slice(&x, fmt, seed, RoundMode::Stochastic);
            for (&xi, &qi) in x.iter().zip(&q) {
                assert!((qi - xi).abs() <= fmt.step() + 1e-7,
                        "x={xi} q={qi} seed={seed}");
            }
        }
    }

    #[test]
    fn zero_maps_to_zero() {
        let (q, s) = quantize_slice(&[0.0; 64], Format::new(4, 8), 3,
                                    RoundMode::Stochastic);
        assert!(q.iter().all(|&v| v == 0.0));
        assert_eq!(s.e, 0.0);
        assert_eq!(s.r, 0.0);
    }
}
