//! The dynamic fixed-point format `<IL, FL>`.
//!
//! `IL` counts the integer bits *including sign* (Gupta et al.'s
//! convention), `FL` the fractional bits; word length is `IL + FL`, step is
//! `2^-FL` and the representable range is `[-2^(IL-1), 2^(IL-1) - 2^-FL]`
//! (two's complement).

use std::fmt;

/// Bounds the controller may move within (DESIGN.md §4). IL >= 1 keeps the
/// sign bit; 24 is where f32 emulation stops being exact, so we never go
/// above it.
pub const IL_RANGE: (i32, i32) = (1, 24);
pub const FL_RANGE: (i32, i32) = (0, 24);

/// Exact `2^e` for integer `e` in `[-126, 127]`, via the f32 exponent field
/// — bit-identical to `kernels/quantize.py::exp2i`.
#[inline]
pub fn exp2i(e: i32) -> f32 {
    f32::from_bits(((e + 127) as u32) << 23)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Format {
    pub il: i32,
    pub fl: i32,
}

impl Format {
    pub const fn new(il: i32, fl: i32) -> Self {
        Self { il, fl }
    }

    /// Clamp into the legal controller range.
    pub fn clamped(self) -> Self {
        Self {
            il: self.il.clamp(IL_RANGE.0, IL_RANGE.1),
            fl: self.fl.clamp(FL_RANGE.0, FL_RANGE.1),
        }
    }

    /// Word length in bits (what the MAC unit pays for).
    pub fn bits(&self) -> i32 {
        self.il + self.fl
    }

    /// Quantization step `2^-FL`.
    pub fn step(&self) -> f32 {
        exp2i(-self.fl)
    }

    /// Largest representable value `2^(IL-1) - 2^-FL` (computed exactly as
    /// the kernel does, including its f32 rounding at IL+FL > 24).
    pub fn max_val(&self) -> f32 {
        exp2i(self.il - 1) - self.step()
    }

    /// Most negative representable value `-2^(IL-1)`.
    pub fn min_val(&self) -> f32 {
        -exp2i(self.il - 1)
    }

    /// Whether `x` lies inside the representable range (the overflow
    /// predicate of the R statistic).
    pub fn contains(&self, x: f32) -> bool {
        x >= self.min_val() && x <= self.max_val()
    }

    /// Integer-grid representation of an (on-grid, in-range) value.
    pub fn to_bits(&self, x: f32) -> i64 {
        (x as f64 * (1u64 << self.fl) as f64).round() as i64
    }

    /// Value of an integer-grid representation.
    pub fn from_bits(&self, b: i64) -> f32 {
        (b as f64 * exp2i(-self.fl) as f64) as f32
    }

    /// Grid bounds in integer representation.
    pub fn bit_bounds(&self) -> (i64, i64) {
        let hi = (1i64 << (self.bits() - 1)) - 1;
        (-hi - 1, hi)
    }
}

impl fmt::Display for Format {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<{},{}>", self.il, self.fl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2i_exact() {
        for e in -126..=127 {
            assert_eq!(exp2i(e), 2.0f32.powi(e), "e={e}");
        }
    }

    #[test]
    fn range_8_8() {
        let f = Format::new(8, 8);
        assert_eq!(f.bits(), 16);
        assert_eq!(f.step(), 1.0 / 256.0);
        assert_eq!(f.max_val(), 128.0 - 1.0 / 256.0);
        assert_eq!(f.min_val(), -128.0);
        assert!(f.contains(127.0));
        assert!(!f.contains(128.0));
        assert!(f.contains(-128.0));
        assert!(!f.contains(-128.5));
    }

    #[test]
    fn bit_roundtrip() {
        let f = Format::new(4, 6);
        for b in f.bit_bounds().0..=f.bit_bounds().1 {
            assert_eq!(f.to_bits(f.from_bits(b)), b);
        }
    }

    #[test]
    fn bit_bounds_match_value_bounds() {
        let f = Format::new(5, 3);
        let (lo, hi) = f.bit_bounds();
        assert_eq!(f.from_bits(lo), f.min_val());
        assert_eq!(f.from_bits(hi), f.max_val());
    }

    #[test]
    fn clamp() {
        assert_eq!(Format::new(40, -3).clamped(), Format::new(24, 0));
        assert_eq!(Format::new(0, 99).clamped(), Format::new(1, 24));
    }

    #[test]
    fn display() {
        assert_eq!(Format::new(4, 9).to_string(), "<4,9>");
    }
}
