//! Device-resident buffers: execute against `PjRtBuffer`s instead of host
//! literals, so state tensors stop round-tripping through the host.
//!
//! The literal execute path ([`super::Executable::run`]) uploads every
//! input and downloads every output each call — fine for batch data, but
//! for parameters and momenta it is an O(model) host↔device round-trip per
//! step that the AOT train modules make unnecessary: they are lowered with
//! input-output aliasing (`donate_argnums` over the 2P state inputs, see
//! `python/compile/aot.py`), so XLA may update the state **in place**.
//! [`DeviceState`] holds the live parameter/momentum `PjRtBuffer`s,
//! [`Executable::run_device`] executes against them, and the step's output
//! buffers simply become the next step's inputs.  Host copies happen only
//! on demand — checkpoint snapshot, rollback restore, fault-injection
//! corruption, inspection — and every one of those state-tensor copies is
//! counted by [`super::host_transfers`] (batch inputs and scalar stat
//! readbacks are not; see the counter's docs for the exact semantics).
//!
//! All PJRT buffer FFI lives in this module on purpose: if a platform's
//! `xla_extension` build behaves differently (e.g. returns the result as a
//! single tuple buffer instead of per-output buffers), [`DeviceRun`]
//! surfaces that as `Fetched` and the engine falls back to the literal
//! path — degraded to the old transfer profile, never wrong.

use anyhow::{Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient};

use super::{note_host_transfers, Executable};

/// One device-resident tensor (a thin owner of a `PjRtBuffer`).
pub struct DeviceBuf {
    buf: PjRtBuffer,
}

impl DeviceBuf {
    pub fn buffer(&self) -> &PjRtBuffer {
        &self.buf
    }

    /// Wrap an executable-output buffer (no transfer involved).
    pub fn from_output(buf: PjRtBuffer) -> DeviceBuf {
        DeviceBuf { buf }
    }

    /// Upload a host literal as an *input-class* buffer (batch data,
    /// scalars, the precision vector) — uncounted by
    /// [`super::host_transfers`], like the host copies the literal execute
    /// path performs internally, but tallied under the `device.h2d_input`
    /// telemetry counter so the eval/step benches can assert a warmed
    /// steady-state loop performs none.
    pub fn from_literal(client: &PjRtClient, lit: &Literal) -> Result<DeviceBuf> {
        crate::telemetry::count("device.h2d_input", 1);
        Self::upload(client, lit)
    }

    /// Upload a *state* tensor (parameter/momentum) — counted against
    /// [`super::host_transfers`], with the direction broken out under the
    /// `device.h2d_state` telemetry counter.
    pub fn from_state_literal(client: &PjRtClient, lit: &Literal) -> Result<DeviceBuf> {
        note_host_transfers(1);
        crate::telemetry::count("device.h2d_state", 1);
        Self::upload(client, lit)
    }

    fn upload(client: &PjRtClient, lit: &Literal) -> Result<DeviceBuf> {
        let buf = client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow::anyhow!("uploading literal to device: {e}"))?;
        Ok(DeviceBuf { buf })
    }

    /// Download a *state* tensor back to the host — counted (direction
    /// broken out under `device.d2h_state`).
    pub fn to_state_literal(&self) -> Result<Literal> {
        note_host_transfers(1);
        crate::telemetry::count("device.d2h_state", 1);
        self.buf
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("downloading device buffer: {e}"))
    }
}

/// What [`Executable::run_device`] hands back.
pub enum DeviceRun {
    /// Per-output device buffers, in `spec.outputs` order — the state
    /// outputs can be fed straight into the next execution.
    Resident(Vec<PjRtBuffer>),
    /// This PJRT build returned one tuple buffer instead of per-output
    /// buffers; the tuple was fetched and untupled on the host.  Callers
    /// should treat this as "device residency unsupported" and fall back
    /// to the literal path.
    Fetched(Vec<Literal>),
}

impl Executable {
    /// Execute with positional *device buffer* inputs (order =
    /// `spec.inputs`).  Validates arity on both sides.
    ///
    /// Inputs declared donated at lowering time (the train modules' 2P
    /// state tensors) must not be reused after this call — take the
    /// corresponding output buffers instead.
    pub fn run_device(&self, inputs: &[&PjRtBuffer]) -> Result<DeviceRun> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "module {}: got {} device inputs, expected {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let mut bufs = self
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow::anyhow!("executing {} (device): {e}", self.spec.name))?;
        anyhow::ensure!(!bufs.is_empty(), "module {}: no result", self.spec.name);
        let dev0 = bufs.swap_remove(0);
        if dev0.len() == self.spec.outputs.len() {
            return Ok(DeviceRun::Resident(dev0));
        }
        // Single tuple result: this build does not untuple on device.
        anyhow::ensure!(
            dev0.len() == 1,
            "module {}: got {} result buffers, expected {} (or 1 tuple)",
            self.spec.name,
            dev0.len(),
            self.spec.outputs.len()
        );
        let tuple = dev0[0]
            .to_literal_sync()
            .with_context(|| format!("fetching tuple result of {}", self.spec.name))?;
        let outs = tuple.to_tuple().context("untupling device result")?;
        anyhow::ensure!(
            outs.len() == self.spec.outputs.len(),
            "module {}: got {} outputs, expected {}",
            self.spec.name,
            outs.len(),
            self.spec.outputs.len()
        );
        Ok(DeviceRun::Fetched(outs))
    }
}

/// The live parameter/momentum buffers of one training run.
pub struct DeviceState {
    params: Vec<DeviceBuf>,
    mom: Vec<DeviceBuf>,
}

impl DeviceState {
    /// Upload host state (counted: `2 * n_params` transfers).
    pub fn upload(client: &PjRtClient, params: &[Literal], mom: &[Literal]) -> Result<DeviceState> {
        anyhow::ensure!(
            params.len() == mom.len(),
            "device state: {} params vs {} momenta",
            params.len(),
            mom.len()
        );
        let up = |lits: &[Literal]| -> Result<Vec<DeviceBuf>> {
            lits.iter()
                .map(|l| DeviceBuf::from_state_literal(client, l))
                .collect()
        };
        Ok(DeviceState { params: up(params)?, mom: up(mom)? })
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Parameter buffers only (the eval module's state inputs).
    pub fn param_buffers(&self) -> impl Iterator<Item = &PjRtBuffer> {
        self.params.iter().map(|b| b.buffer())
    }

    /// All state buffers in train-module input order: params then momenta.
    pub fn input_buffers(&self) -> impl Iterator<Item = &PjRtBuffer> {
        self.params
            .iter()
            .chain(self.mom.iter())
            .map(|b| b.buffer())
    }

    /// Adopt a step's output buffers as the new state (no transfer — this
    /// is the whole point: outputs stay on device).
    pub fn replace(&mut self, params: Vec<PjRtBuffer>, mom: Vec<PjRtBuffer>) {
        assert_eq!(params.len(), self.params.len());
        assert_eq!(mom.len(), self.mom.len());
        self.params = params.into_iter().map(DeviceBuf::from_output).collect();
        self.mom = mom.into_iter().map(DeviceBuf::from_output).collect();
    }

    /// Download the full state to host literals (counted: `2 * n_params`) —
    /// checkpoint save, rollback snapshot, inspection.
    pub fn snapshot(&self) -> Result<(Vec<Literal>, Vec<Literal>)> {
        let down = |bufs: &[DeviceBuf]| -> Result<Vec<Literal>> {
            bufs.iter().map(|b| b.to_state_literal()).collect()
        };
        Ok((down(&self.params)?, down(&self.mom)?))
    }

    /// Download one tensor (counted) — fault-injection reads.
    pub fn download(&self, mom: bool, idx: usize) -> Result<Literal> {
        let store = if mom { &self.mom } else { &self.params };
        store[idx].to_state_literal()
    }

    /// Overwrite one tensor from a host literal (counted) — fault-injection
    /// writes.
    pub fn set(
        &mut self,
        client: &PjRtClient,
        mom: bool,
        idx: usize,
        lit: &Literal,
    ) -> Result<()> {
        let store = if mom { &mut self.mom } else { &mut self.params };
        store[idx] = DeviceBuf::from_state_literal(client, lit)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{host_transfers, literal_f32, to_vec_f32};

    fn client() -> PjRtClient {
        PjRtClient::cpu().expect("PJRT CPU client")
    }

    #[test]
    fn state_upload_download_roundtrip_is_counted() {
        let c = client();
        let lit = literal_f32(&[1.0, -2.0, 3.5, 0.25], &[2, 2]).unwrap();
        let before = host_transfers();
        let buf = DeviceBuf::from_state_literal(&c, &lit).unwrap();
        assert_eq!(host_transfers(), before + 1, "upload counts once");
        let back = buf.to_state_literal().unwrap();
        assert_eq!(host_transfers(), before + 2, "download counts once");
        assert_eq!(to_vec_f32(&back).unwrap(), vec![1.0, -2.0, 3.5, 0.25]);
    }

    #[test]
    fn input_uploads_are_not_counted() {
        let c = client();
        let lit = literal_f32(&[7.0; 8], &[8]).unwrap();
        let before = host_transfers();
        let _buf = DeviceBuf::from_literal(&c, &lit).unwrap();
        assert_eq!(host_transfers(), before, "batch-class uploads are free");
    }

    #[test]
    fn input_and_state_uploads_tick_distinct_counters() {
        let c = client();
        let lit = literal_f32(&[1.0, 2.0], &[2]).unwrap();
        let input_before = crate::telemetry::counter("device.h2d_input");
        let state_before = crate::telemetry::counter("device.h2d_state");
        let _i = DeviceBuf::from_literal(&c, &lit).unwrap();
        assert_eq!(crate::telemetry::counter("device.h2d_input"), input_before + 1);
        assert_eq!(crate::telemetry::counter("device.h2d_state"), state_before);
        let _s = DeviceBuf::from_state_literal(&c, &lit).unwrap();
        assert_eq!(
            crate::telemetry::counter("device.h2d_input"),
            input_before + 1,
            "state uploads must not masquerade as input uploads"
        );
        assert_eq!(crate::telemetry::counter("device.h2d_state"), state_before + 1);
    }

    #[test]
    fn device_state_snapshot_matches_upload() {
        let c = client();
        let params = vec![
            literal_f32(&[1.0, 2.0], &[2]).unwrap(),
            literal_f32(&[3.0], &[1]).unwrap(),
        ];
        let mom = vec![
            literal_f32(&[0.0, 0.5], &[2]).unwrap(),
            literal_f32(&[-1.0], &[1]).unwrap(),
        ];
        let before = host_transfers();
        let ds = DeviceState::upload(&c, &params, &mom).unwrap();
        assert_eq!(host_transfers(), before + 4);
        assert_eq!(ds.n_params(), 2);
        let (p2, m2) = ds.snapshot().unwrap();
        assert_eq!(host_transfers(), before + 8);
        for (a, b) in params.iter().zip(&p2) {
            assert_eq!(to_vec_f32(a).unwrap(), to_vec_f32(b).unwrap());
        }
        for (a, b) in mom.iter().zip(&m2) {
            assert_eq!(to_vec_f32(a).unwrap(), to_vec_f32(b).unwrap());
        }
    }

    #[test]
    fn set_and_download_one_tensor() {
        let c = client();
        let params = vec![literal_f32(&[1.0, 2.0], &[2]).unwrap()];
        let mom = vec![literal_f32(&[0.0, 0.0], &[2]).unwrap()];
        let mut ds = DeviceState::upload(&c, &params, &mom).unwrap();
        let patched = literal_f32(&[9.0, 2.0], &[2]).unwrap();
        ds.set(&c, false, 0, &patched).unwrap();
        let back = ds.download(false, 0).unwrap();
        assert_eq!(to_vec_f32(&back).unwrap(), vec![9.0, 2.0]);
        let m = ds.download(true, 0).unwrap();
        assert_eq!(to_vec_f32(&m).unwrap(), vec![0.0, 0.0]);
    }
}
