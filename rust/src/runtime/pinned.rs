//! Pre-pinned input literals: allocate once, refill in place every step.
//!
//! The PJRT execute path takes host [`Literal`]s by reference, so the only
//! reason to construct a fresh `Literal` per iteration is convenience — and
//! it shows up as allocator traffic and host-copy churn on the L3 hot path
//! (ROADMAP "Perf").  A [`PinnedF32`]/[`PinnedI32`] owns one literal of a
//! fixed shape and overwrites its payload via `copy_raw_from`, so the
//! training step's batch/precision/scalar inputs are *zero-allocation*
//! after [`crate::trainer::StepEngine`] construction.
//!
//! Creation goes through [`super::literal_f32`]/[`super::literal_i32`] and
//! therefore counts against [`super::literal_builds`]; `fill` does not —
//! that counter is how the `bench step` micro-benchmark and the integration
//! tests prove the hot path stays allocation-free.

use anyhow::Result;
use xla::Literal;

/// A fixed-shape f32 literal refilled in place (never reallocated).
pub struct PinnedF32 {
    lit: Literal,
    len: usize,
}

impl PinnedF32 {
    /// Allocate a zero-filled literal of `shape` (`&[]` pins a scalar).
    pub fn zeros(shape: &[usize]) -> Result<PinnedF32> {
        let len = shape.iter().product::<usize>().max(1);
        let lit = super::literal_f32(&vec![0.0f32; len], shape)?;
        Ok(PinnedF32 { lit, len })
    }

    /// Overwrite the payload; `data` must match the pinned element count.
    pub fn fill(&mut self, data: &[f32]) -> Result<()> {
        anyhow::ensure!(
            data.len() == self.len,
            "pinned fill: {} elems into a {}-elem literal",
            data.len(),
            self.len
        );
        self.lit
            .copy_raw_from(data)
            .map_err(|e| anyhow::anyhow!("refilling pinned literal: {e}"))
    }

    /// Overwrite a pinned scalar.
    pub fn set_scalar(&mut self, v: f32) -> Result<()> {
        self.fill(&[v])
    }

    pub fn literal(&self) -> &Literal {
        &self.lit
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// A fixed-shape i32 literal refilled in place (never reallocated).
pub struct PinnedI32 {
    lit: Literal,
    len: usize,
}

impl PinnedI32 {
    pub fn zeros(shape: &[usize]) -> Result<PinnedI32> {
        let len = shape.iter().product::<usize>().max(1);
        let lit = super::literal_i32(&vec![0i32; len], shape)?;
        Ok(PinnedI32 { lit, len })
    }

    pub fn fill(&mut self, data: &[i32]) -> Result<()> {
        anyhow::ensure!(
            data.len() == self.len,
            "pinned fill: {} elems into a {}-elem literal",
            data.len(),
            self.len
        );
        self.lit
            .copy_raw_from(data)
            .map_err(|e| anyhow::anyhow!("refilling pinned literal: {e}"))
    }

    pub fn literal(&self) -> &Literal {
        &self.lit
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{literal_builds, to_vec_f32};

    #[test]
    fn refill_changes_payload_not_identity() {
        let mut p = PinnedF32::zeros(&[2, 2]).unwrap();
        assert_eq!(to_vec_f32(p.literal()).unwrap(), vec![0.0; 4]);
        p.fill(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(to_vec_f32(p.literal()).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        p.fill(&[5.0, 6.0, 7.0, 8.0]).unwrap();
        assert_eq!(to_vec_f32(p.literal()).unwrap(), vec![5.0, 6.0, 7.0, 8.0]);
        assert!(p.fill(&[1.0]).is_err(), "length mismatch must be rejected");
    }

    #[test]
    fn scalar_pin_and_set() {
        let mut p = PinnedF32::zeros(&[]).unwrap();
        p.set_scalar(0.25).unwrap();
        assert_eq!(p.literal().get_first_element::<f32>().unwrap(), 0.25);
        p.set_scalar(-3.5).unwrap();
        assert_eq!(p.literal().get_first_element::<f32>().unwrap(), -3.5);
    }

    #[test]
    fn i32_refill() {
        let mut p = PinnedI32::zeros(&[3]).unwrap();
        p.fill(&[7, 8, 9]).unwrap();
        assert_eq!(p.literal().to_vec::<i32>().unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn fill_does_not_count_as_literal_build() {
        let mut p = PinnedF32::zeros(&[8]).unwrap();
        let before = literal_builds();
        for i in 0..100 {
            p.fill(&[i as f32; 8]).unwrap();
        }
        assert_eq!(
            literal_builds(),
            before,
            "refill must not construct literals"
        );
    }
}
