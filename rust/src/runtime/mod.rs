//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Mirrors `/opt/xla-example/load_hlo`: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax≥0.5 serialized protos).
//!
//! Executables are cached per module name, so a training run compiles its
//! step exactly once.  On top of the host-literal path, [`device`] keeps
//! *state* tensors (parameters/momenta) resident on the device between
//! executions: [`Executable::run_device`] consumes `PjRtBuffer`s and the
//! step's output buffers become the next step's inputs, so the steady-state
//! hot loop performs **zero** host↔device parameter transfers
//! ([`host_transfers`] counts them, mirroring [`literal_builds`]).

pub mod device;
pub mod manifest;
pub mod pinned;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{Context, Result};
use xla::{FromRawBytes, Literal, PjRtClient, PjRtLoadedExecutable};

pub use device::{DeviceBuf, DeviceRun, DeviceState};
pub use manifest::{DType, Manifest, ModelMeta, ModuleSpec, TensorSpec};
pub use pinned::{PinnedF32, PinnedI32};

use crate::resilience::FaultInjector;

/// Telemetry counter name behind [`literal_builds`].
pub const CTR_LITERAL_BUILDS: &str = "runtime.literal_builds";
/// Telemetry counter name behind [`host_transfers`].
pub const CTR_HOST_TRANSFERS: &str = "runtime.host_transfers";

/// Running count of `Literal` constructions on this thread.
///
/// Every literal built through [`literal_f32`]/[`literal_i32`] (and thus
/// every [`PinnedF32`]/[`PinnedI32`] *creation*, but not refills) bumps the
/// counter.  Tests and `repro bench step` snapshot it around the hot loop
/// to prove `Trainer::step` performs zero per-iteration literal
/// allocations for its batch/precision inputs.
///
/// Since the telemetry subsystem landed this is a thin shim over the
/// `runtime.literal_builds` counter in [`crate::telemetry`] — same
/// thread-local semantics, but the count now also appears in snapshots,
/// traces and `History::summary_json()`.
pub fn literal_builds() -> u64 {
    crate::telemetry::counter(CTR_LITERAL_BUILDS)
}

fn count_literal_build() {
    crate::telemetry::count(CTR_LITERAL_BUILDS, 1);
}

/// Running count of parameter/momentum **state-tensor** transfers between
/// host and device on this thread, in tensors (one upload or one download
/// of one tensor = one count).
///
/// Per-step *batch* inputs (x/y/lr/seed/prec) and scalar stat readbacks are
/// intentionally uncounted — they are O(batch) traffic every step path must
/// pay.  What this counter isolates is the O(model) round-trip the
/// device-resident path ([`device::DeviceState`]) removes: a donated step
/// adds **zero**, the literal fallback adds `4 * n_params` (2P up + 2P
/// down), and snapshot/restore/reinit/corrupt operations count their
/// on-demand copies.  `repro bench step`, `benches/bench_step.rs`, and the
/// integration tests snapshot it around the hot loop, exactly like
/// [`literal_builds`].  Shimmed over the `runtime.host_transfers`
/// telemetry counter (see [`literal_builds`] for the rationale).
pub fn host_transfers() -> u64 {
    crate::telemetry::counter(CTR_HOST_TRANSFERS)
}

pub(crate) fn note_host_transfers(n: u64) {
    crate::telemetry::count(CTR_HOST_TRANSFERS, n);
}

/// A compiled module plus its manifest spec.
pub struct Executable {
    pub spec: ModuleSpec,
    exe: PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional inputs (order = `spec.inputs`).
    ///
    /// Validates arity, unpacks the tuple result, and validates output
    /// arity.  Returns outputs in `spec.outputs` order.
    pub fn run<L: std::borrow::Borrow<Literal>>(&self, inputs: &[L]) -> Result<Vec<Literal>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "module {}: got {} inputs, expected {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let bufs = self
            .exe
            .execute(inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.spec.name))?;
        let tuple = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.spec.name))?;
        let outs = tuple.to_tuple().context("untupling result")?;
        anyhow::ensure!(
            outs.len() == self.spec.outputs.len(),
            "module {}: got {} outputs, expected {}",
            self.spec.name,
            outs.len(),
            self.spec.outputs.len()
        );
        Ok(outs)
    }

    /// Scalar f32 view of output `idx` (loss/acc readbacks).
    pub fn out_f32(outs: &[Literal], idx: usize) -> Result<f32> {
        Ok(outs[idx].get_first_element::<f32>()?)
    }
}

/// The runtime: one PJRT CPU client + the manifest + an executable cache.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub dir: PathBuf,
    cache: HashMap<String, std::rc::Rc<Executable>>,
    /// When armed, `read-fail` fault specs fire inside [`Runtime::load`] and
    /// [`Runtime::load_params`] retry loops — not just the dataset load.
    fault_injector: Option<Rc<RefCell<FaultInjector>>>,
}

impl Runtime {
    /// Create from the default artifacts directory (see
    /// [`crate::artifacts_dir`]).
    pub fn create() -> Result<Runtime> {
        Self::with_dir(crate::artifacts_dir())
    }

    pub fn with_dir<P: Into<PathBuf>>(dir: P) -> Result<Runtime> {
        let dir = dir.into();
        let manifest = Manifest::load_dir(&dir)?;
        // Perf (EXPERIMENTS.md §Perf/L3-1): on small-core hosts the TFRT CPU
        // client's Eigen thread pool burns more time in futex churn than it
        // saves — multi-threaded eigen cost ~19% wall and ~6x sys time on
        // the 1-core CI box.  Respect an explicit user setting.
        if std::env::var_os("XLA_FLAGS").is_none() {
            let threads = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            if threads <= 2 {
                std::env::set_var("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false");
            }
        }
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        crate::log_debug!(
            "runtime: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime { client, manifest, dir, cache: HashMap::new(), fault_injector: None })
    }

    /// Route `read-fail` fault injection through this runtime's artifact and
    /// parameter loads.  The injector is shared (the session also draws
    /// loss/bitflip faults from it), hence the `Rc<RefCell<_>>`.
    pub fn arm_faults(&mut self, injector: Rc<RefCell<FaultInjector>>) {
        self.fault_injector = Some(injector);
    }

    pub fn disarm_faults(&mut self) {
        self.fault_injector = None;
    }

    /// Draw an injected read failure for `what`, if one is armed and due.
    fn injected_read_failure(&self, what: &str) -> Option<anyhow::Error> {
        self.fault_injector
            .as_ref()
            .and_then(|inj| inj.borrow_mut().take_read_failure(what))
    }

    /// Load + compile a module (cached).
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.module(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t = crate::util::Stopwatch::start();
        // artifact reads go over whatever filesystem hosts the repo (often
        // network-mounted on CI) — retry transient failures before giving up
        let proto = crate::resilience::retry_with_backoff(
            &format!("loading artifact {name}"),
            3,
            100,
            |_| {
                if let Some(e) = self.injected_read_failure(&format!("artifact {name}")) {
                    return Err(e);
                }
                xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
            },
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        crate::log_info!("runtime: compiled {name} in {:.2}s", t.elapsed_s());
        let e = std::rc::Rc::new(Executable { spec, exe });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Load a model's initial parameters from `artifacts/<model>_params.npz`
    /// in manifest parameter order.
    pub fn load_params(&self, model: &str) -> Result<Vec<Literal>> {
        let meta = self.manifest.model(model)?;
        let path = self.dir.join(format!("{model}_params.npz"));
        let named = crate::resilience::retry_with_backoff(
            &format!("loading {model} params"),
            3,
            100,
            |_| {
                if let Some(e) = self.injected_read_failure(&format!("{model} params")) {
                    return Err(e);
                }
                Literal::read_npz(&path, &())
                    .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))
            },
        )?;
        let mut by_name: HashMap<String, Literal> = named
            .into_iter()
            .map(|(mut n, l)| {
                // npz entries may carry a ".npy" suffix
                if let Some(stripped) = n.strip_suffix(".npy") {
                    n = stripped.to_string();
                }
                (n, l)
            })
            .collect();
        meta.params
            .iter()
            .map(|p| {
                let lit = by_name
                    .remove(&p.name)
                    .with_context(|| format!("{path:?} missing param '{}'", p.name))?;
                let got = lit.array_shape().map_err(|e| anyhow::anyhow!("{e}"))?;
                let want: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                anyhow::ensure!(
                    got.dims() == want.as_slice(),
                    "param {}: npz shape {:?} != manifest {:?}",
                    p.name,
                    got.dims(),
                    want
                );
                Ok(lit)
            })
            .collect()
    }

    /// Zero-filled literals matching the model's parameter shapes (momentum
    /// buffers).
    pub fn zeros_like_params(&self, model: &str) -> Result<Vec<Literal>> {
        let meta = self.manifest.model(model)?;
        meta.params
            .iter()
            .map(|p| {
                let n: usize = p.shape.iter().product();
                literal_f32(&vec![0.0f32; n], &p.shape)
            })
            .collect()
    }
}

/// Build an f32 literal of the given shape from a slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(data.len() == n, "literal: {} elems for shape {shape:?}", data.len());
    count_literal_build();
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("{e}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(data.len() == n, "literal: {} elems for shape {shape:?}", data.len());
    count_literal_build();
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    if dims.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("{e}"))
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))
}

/// Deep-copy an f32 literal (the xla crate's `Literal` has no `Clone`);
/// counts as a literal build, not a host transfer.
pub fn clone_literal_f32(lit: &Literal) -> Result<Literal> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    literal_f32(&to_vec_f32(lit)?, &dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let s = literal_f32(&[7.5], &[]).unwrap();
        assert_eq!(s.get_first_element::<f32>().unwrap(), 7.5);
        assert!(literal_f32(&[1.0], &[3]).is_err());
        let i = literal_i32(&[1, 2], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn builder_calls_are_counted() {
        let before = literal_builds();
        literal_f32(&[1.0], &[]).unwrap();
        literal_i32(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(literal_builds(), before + 2);
    }

    #[test]
    fn clone_preserves_shape_and_payload() {
        let l = literal_f32(&[1.0, -2.5, 3.0, 4.0, 5.0, 6.0], &[3, 2]).unwrap();
        let tx_before = host_transfers();
        let c = clone_literal_f32(&l).unwrap();
        assert_eq!(to_vec_f32(&c).unwrap(), to_vec_f32(&l).unwrap());
        let (a, b) = (l.array_shape().unwrap(), c.array_shape().unwrap());
        assert_eq!(a.dims(), b.dims());
        assert_eq!(host_transfers(), tx_before, "clone is host-side only");
    }

    #[test]
    fn host_transfer_notes_accumulate() {
        let before = host_transfers();
        note_host_transfers(3);
        note_host_transfers(1);
        assert_eq!(host_transfers(), before + 4);
    }

    #[test]
    fn counter_shims_surface_in_telemetry() {
        let before = crate::telemetry::snapshot();
        literal_f32(&[0.0], &[]).unwrap();
        note_host_transfers(2);
        let delta = crate::telemetry::snapshot().diff(&before);
        assert_eq!(delta.counter(CTR_LITERAL_BUILDS), 1);
        assert_eq!(delta.counter(CTR_HOST_TRANSFERS), 2);
    }
}
