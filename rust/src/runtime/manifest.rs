//! Typed view of `artifacts/manifest.json` (written by `aot.py`).
//!
//! The Rust side never hard-codes argument order, shapes, or quantize-site
//! layout — it all flows from here.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::policy::Class;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }

    pub fn to_xla(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        let name = j.get("name").as_str().context("tensor name")?.to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .context("tensor shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.get("dtype").as_str().unwrap_or("f32"))?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

#[derive(Debug, Clone)]
pub struct SiteSpec {
    pub name: String,
    pub class: Class,
}

#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub file: String,
    /// `train` | `eval` | `quantize` | `qmatmul`.
    pub kind: String,
    pub model: Option<String>,
    pub batch: usize,
    pub quantized: bool,
    pub stochastic: bool,
    /// Lowered with `donate_argnums` over the state inputs, so XLA may
    /// alias parameters/momenta in place on the device-buffer path.
    pub donated: bool,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sites: Vec<SiteSpec>,
}

impl ModuleSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .with_context(|| format!("module {}: no input '{name}'", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|t| t.name == name)
            .with_context(|| format!("module {}: no output '{name}'", self.name))
    }

    /// Indices of this module's stat-vector slots belonging to `class`.
    pub fn site_indices(&self, class: Class) -> Vec<usize> {
        self.sites
            .iter()
            .enumerate()
            .filter(|(_, s)| s.class == class)
            .map(|(i, _)| i)
            .collect()
    }
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub params: Vec<ParamSpec>,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
}

impl ModelMeta {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub modules: BTreeMap<String, ModuleSpec>,
    pub models: BTreeMap<String, ModelMeta>,
    pub train_batch: usize,
    pub eval_batch: usize,
}

impl Manifest {
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).context("manifest.json")?;
        let mut modules = BTreeMap::new();
        for (name, m) in j.get("modules").as_obj().context("modules")? {
            let sites = m
                .get("sites")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|s| -> Result<SiteSpec> {
                    Ok(SiteSpec {
                        name: s.get("name").as_str().context("site name")?.into(),
                        class: Class::from_str(
                            s.get("class").as_str().context("site class")?,
                        )
                        .context("site class value")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let parse_tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                m.get(key)
                    .as_arr()
                    .with_context(|| format!("module {name}: {key}"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            modules.insert(
                name.clone(),
                ModuleSpec {
                    name: name.clone(),
                    file: m.get("file").as_str().context("file")?.into(),
                    kind: m.get("kind").as_str().context("kind")?.into(),
                    model: m.get("model").as_str().map(|s| s.to_string()),
                    batch: m.get("batch").as_usize().unwrap_or(0),
                    quantized: m.get("quantized").as_bool().unwrap_or(false),
                    stochastic: m.get("stochastic").as_bool().unwrap_or(false),
                    donated: m.get("donated").as_bool().unwrap_or(false),
                    inputs: parse_tensors("inputs")?,
                    outputs: parse_tensors("outputs")?,
                    sites,
                },
            );
        }
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").as_obj().context("models")? {
            let params = m
                .get("params")
                .as_arr()
                .context("model params")?
                .iter()
                .map(|p| -> Result<ParamSpec> {
                    Ok(ParamSpec {
                        name: p.get("name").as_str().context("param name")?.into(),
                        shape: p
                            .get("shape")
                            .as_arr()
                            .context("param shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<Vec<_>>>()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelMeta {
                    params,
                    input_shape: m
                        .get("input_shape")
                        .as_arr()
                        .context("input_shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?,
                    num_classes: m.get("num_classes").as_usize().unwrap_or(10),
                },
            );
        }
        Ok(Manifest {
            modules,
            models,
            train_batch: j.get("train_batch").as_usize().unwrap_or(64),
            eval_batch: j.get("eval_batch").as_usize().unwrap_or(100),
        })
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .get(name)
            .with_context(|| format!("manifest has no module '{name}'"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .with_context(|| format!("manifest has no model '{name}'"))
    }

    /// Train-step module name for (model, rounding/float choice).
    pub fn train_module_name(model: &str, rounding: crate::policy::Rounding) -> String {
        match rounding {
            crate::policy::Rounding::Stochastic => format!("{model}_train"),
            crate::policy::Rounding::Nearest => format!("{model}_train_nearest"),
            crate::policy::Rounding::Float => format!("{model}_train_float"),
        }
    }

    pub fn eval_module_name(model: &str, quantized: bool) -> String {
        if quantized {
            format!("{model}_eval")
        } else {
            format!("{model}_eval_float")
        }
    }

    /// Do this model's eval modules emit per-example outputs (`loss_vec` /
    /// `correct_vec`)?  Newer artifacts do, which lets the engine mask pad
    /// entries exactly on non-multiple test sets; legacy artifacts emit
    /// whole-batch scalars and keep the approximate tail path.
    pub fn eval_per_example(&self, model: &str) -> bool {
        [true, false].iter().any(|&q| {
            self.modules
                .get(&Self::eval_module_name(model, q))
                .is_some_and(|m| m.outputs.iter().any(|t| t.name == "loss_vec"))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "eval_batch": 100, "train_batch": 64,
      "models": {"mlp": {"input_shape": [784], "num_classes": 10,
        "params": [{"name": "w1", "shape": [784, 256]},
                   {"name": "b1", "shape": [256]}]}},
      "modules": {"mlp_train": {
        "kind": "train", "model": "mlp", "batch": 64, "file": "mlp_train.hlo.txt",
        "quantized": true, "stochastic": true,
        "inputs": [{"name": "w1", "shape": [784, 256], "dtype": "f32"},
                   {"name": "y", "shape": [64], "dtype": "i32"}],
        "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
        "sites": [{"name": "input", "class": "act"},
                  {"name": "g_w1", "class": "grad"},
                  {"name": "w_w1", "class": "weight"}]}}}"#;

    #[test]
    fn parse_mini() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.train_batch, 64);
        let spec = m.module("mlp_train").unwrap();
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[1].dtype, DType::I32);
        assert_eq!(spec.input_index("y").unwrap(), 1);
        assert!(spec.input_index("nope").is_err());
        assert_eq!(spec.site_indices(Class::Grad), vec![1]);
        let meta = m.model("mlp").unwrap();
        assert_eq!(meta.param_count(), 784 * 256 + 256);
    }

    #[test]
    fn eval_per_example_detection() {
        let mini = Manifest::parse(MINI).unwrap();
        assert!(!mini.eval_per_example("mlp"), "no eval module at all");
        let with_vec = r#"{
          "models": {"mlp": {"input_shape": [784], "params": []}},
          "modules": {"mlp_eval": {
            "kind": "eval", "model": "mlp", "batch": 100, "file": "e.hlo.txt",
            "inputs": [], "donated": false,
            "outputs": [{"name": "loss_vec", "shape": [100], "dtype": "f32"},
                        {"name": "correct_vec", "shape": [100], "dtype": "f32"}]}}}"#;
        let m = Manifest::parse(with_vec).unwrap();
        assert!(m.eval_per_example("mlp"));
        assert!(!m.module("mlp_eval").unwrap().donated);
    }

    #[test]
    fn module_names() {
        use crate::policy::Rounding;
        assert_eq!(Manifest::train_module_name("lenet", Rounding::Stochastic),
                   "lenet_train");
        assert_eq!(Manifest::train_module_name("mlp", Rounding::Nearest),
                   "mlp_train_nearest");
        assert_eq!(Manifest::train_module_name("mlp", Rounding::Float),
                   "mlp_train_float");
        assert_eq!(Manifest::eval_module_name("mlp", false), "mlp_eval_float");
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load_dir(&dir).unwrap();
            assert!(m.modules.contains_key("lenet_train"));
            assert!(m.models.contains_key("lenet"));
            let spec = m.module("lenet_train").unwrap();
            assert_eq!(spec.sites.len(), 21);
            // prec is always the last input
            assert_eq!(spec.inputs.last().unwrap().name, "prec");
        }
    }
}
