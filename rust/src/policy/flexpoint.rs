//! FlexPoint-style controller (Köster et al., NeurIPS'17) — the §5
//! "future work" scheme the paper wishes it had: a fixed word length with a
//! shared exponent steered *predictively* from value statistics, rather
//! than reactively from single-step overflow.
//!
//! Köster's Autoflex predicts each tensor's max value from its recent
//! history and sets the exponent so the predicted max (plus headroom
//! standard deviations) fits.  Our artifact exposes overflow rate rather
//! than raw amax, so the predictor runs on the *saturation margin*: it
//! tracks an EWMA of the overflow rate per class and moves the radix so
//! that predicted overflow stays just below a tiny target — raising IL
//! immediately on any overflow burst (FlexPoint is paranoid about clipping,
//! which corrupts dot products), and lowering it only after a long
//! clean streak (the prediction horizon).
//!
//! | | Courbariaux | FlexPoint (this) |
//! |---|---|---|
//! | shrink IL | `2R <= R_max` next step | after `horizon` clean steps |
//! | grow IL | `R > R_max` (+1) | any overflow (+1, burst +2) |

use super::{Class, Feedback, Policy, PrecState, Rounding};
use crate::fixedpoint::Format;

#[derive(Debug, Clone)]
pub struct FlexpointPolicy {
    /// Word length (16 in Flexpoint's flex16+5).
    pub width: i32,
    /// Clean-streak length required before reclaiming an integer bit.
    pub horizon: u32,
    /// EWMA decay for the overflow-rate predictor.
    pub alpha: f32,
    streak: [u32; 3],
    ewma_r: [f32; 3],
    init: PrecState,
}

impl FlexpointPolicy {
    pub fn new(width: i32, init: PrecState) -> Self {
        let fit = |f: Format| {
            let il = f.il.clamp(1, width - 1);
            Format::new(il, width - il)
        };
        Self {
            width,
            horizon: 100,
            alpha: 0.1,
            streak: [0; 3],
            ewma_r: [0.0; 3],
            init: PrecState {
                weights: fit(init.weights),
                acts: fit(init.acts),
                grads: fit(init.grads),
            },
        }
    }
}

impl Policy for FlexpointPolicy {
    fn name(&self) -> &'static str {
        "flexpoint"
    }

    fn init(&self) -> PrecState {
        self.init
    }

    fn update(&mut self, current: PrecState, fb: &Feedback) -> PrecState {
        let mut next = current;
        for (i, class) in [Class::Weight, Class::Act, Class::Grad]
            .into_iter()
            .enumerate()
        {
            let r = fb.class(class).r;
            self.ewma_r[i] = (1.0 - self.alpha) * self.ewma_r[i] + self.alpha * r;
            let fmt = current.get(class);
            let il = if r > 0.0 {
                // clipping happened: escalate now; a burst (predictor also
                // hot) jumps two bits, mirroring Autoflex's margin factor.
                self.streak[i] = 0;
                fmt.il + if self.ewma_r[i] > 0.01 { 2 } else { 1 }
            } else {
                self.streak[i] += 1;
                if self.streak[i] >= self.horizon && self.ewma_r[i] < 1e-4 {
                    self.streak[i] = 0;
                    fmt.il - 1
                } else {
                    fmt.il
                }
            };
            let il = il.clamp(1, self.width - 1);
            next.set(class, Format::new(il, self.width - il));
        }
        next
    }

    fn rounding(&self) -> Rounding {
        // Flexpoint itself is rounding-agnostic (Table 1: "N/A"); we pair
        // it with stochastic rounding like the rest of the repo.
        Rounding::Stochastic
    }

    /// Grow the shared word length and restart the clean-streak clocks so
    /// the reclaim rule cannot immediately undo the escalation.
    fn escalate(&mut self, current: PrecState, _class: Option<Class>) -> PrecState {
        self.width = (self.width + 2).min(crate::fixedpoint::IL_RANGE.1);
        self.streak = [0; 3];
        let fit = |f: Format| {
            let il = (f.il + 1).clamp(1, self.width - 1);
            Format::new(il, self.width - il)
        };
        PrecState {
            weights: fit(current.weights),
            acts: fit(current.acts),
            grads: fit(current.grads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ClassStats;

    fn fb(r: f32) -> Feedback {
        let s = ClassStats { e: 0.0, r };
        Feedback { iter: 0, loss: 1.0, weights: s, acts: s, grads: s }
    }

    fn policy() -> FlexpointPolicy {
        FlexpointPolicy::new(16, PrecState::uniform(Format::new(4, 12)))
    }

    #[test]
    fn width_always_constant() {
        let mut p = policy();
        let mut st = p.init();
        let mut rng = crate::util::rng::Pcg32::seeded(2);
        for _ in 0..1000 {
            st = p.update(st, &fb(if rng.next_f32() < 0.05 { 0.01 } else { 0.0 }));
            assert_eq!(st.weights.bits(), 16);
            assert_eq!(st.grads.bits(), 16);
        }
    }

    #[test]
    fn overflow_escalates_immediately() {
        let mut p = policy();
        let st = p.update(p.init(), &fb(0.001));
        assert_eq!(st.weights.il, 5);
    }

    #[test]
    fn burst_escalates_by_two() {
        let mut p = policy();
        let mut st = p.init();
        for _ in 0..10 {
            st = p.update(st, &fb(0.5)); // sustained heavy clipping
        }
        // after the EWMA warms past 1%, steps jump by 2
        assert_eq!(st.weights.il, 15); // clamped at width-1
    }

    #[test]
    fn reclaims_only_after_clean_horizon() {
        let mut p = policy();
        let mut st = p.update(p.init(), &fb(0.001)); // il -> 5
        for i in 0..p.horizon * 3 {
            st = p.update(st, &fb(0.0));
            if i < 50 {
                assert_eq!(st.weights.il, 5, "reclaimed too early at {i}");
            }
        }
        assert!(st.weights.il < 5, "never reclaimed");
    }

    #[test]
    fn hysteresis_beats_courbariaux_on_bursty_traffic() {
        // bursty overflow every 30 steps: courbariaux oscillates (shrinks
        // right back), flexpoint holds the safe radix.
        use crate::policy::CourbariauxPolicy;
        let mut flex = policy();
        let mut cour =
            CourbariauxPolicy::new(16, 1e-4, PrecState::uniform(Format::new(4, 12)));
        let mut sf = flex.init();
        let mut sc = cour.init();
        let mut flex_clip_steps = 0;
        let mut cour_clip_steps = 0;
        for i in 0..300 {
            let r = if i % 30 == 29 { 0.01 } else { 0.0 };
            // a step that *would* clip if IL dropped below 5
            if r > 0.0 {
                flex_clip_steps += (sf.weights.il < 5) as u32;
                cour_clip_steps += (sc.weights.il < 5) as u32;
            }
            sf = flex.update(sf, &fb(r));
            sc = cour.update(sc, &fb(r));
        }
        assert!(flex_clip_steps <= cour_clip_steps,
                "flex {flex_clip_steps} vs cour {cour_clip_steps}");
    }
}
