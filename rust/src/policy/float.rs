//! The fp32 baseline (paper Fig. 4 "baseline"): runs the non-quantized
//! artifact; reported bit-width is the constant 32.

use super::{Feedback, Policy, PrecState, Rounding};
use crate::fixedpoint::Format;

#[derive(Debug, Clone, Default)]
pub struct FloatPolicy;

impl FloatPolicy {
    pub fn new() -> Self {
        Self
    }
}

impl Policy for FloatPolicy {
    fn name(&self) -> &'static str {
        "float"
    }

    fn init(&self) -> PrecState {
        // Reported as 32-bit words; the float artifact ignores `prec`.
        PrecState::uniform(Format::new(16, 16))
    }

    fn update(&mut self, current: PrecState, _fb: &Feedback) -> PrecState {
        current
    }

    fn rounding(&self) -> Rounding {
        Rounding::Float
    }

    fn is_float(&self) -> bool {
        true
    }

    /// fp32 has nowhere to escalate to (and `prec` is ignored anyway).
    fn can_escalate(&self) -> bool {
        false
    }

    fn escalate(&mut self, current: PrecState, _class: Option<super::Class>) -> PrecState {
        current
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ClassStats;

    #[test]
    fn is_32_bits_and_inert() {
        let mut p = FloatPolicy::new();
        assert!(p.is_float());
        let st = p.init();
        assert_eq!(st.weights.bits(), 32);
        let s = ClassStats { e: 1.0, r: 1.0 };
        let fb = Feedback { iter: 0, loss: 1.0, weights: s, acts: s, grads: s };
        assert_eq!(p.update(st, &fb), st);
    }
}
