//! Epoch/iteration-scheduled precision growth — the "easily conceivable"
//! alternative the paper's introduction mentions but leaves uninvestigated
//! (§1).  Included as an ablation: bit-width grows by one every
//! `grow_every` iterations regardless of feedback.  The ablation bench
//! compares it against feedback-driven scaling.

use super::{Class, Feedback, Policy, PrecState, Rounding};
use crate::fixedpoint::Format;

#[derive(Debug, Clone)]
pub struct SchedulePolicy {
    init: PrecState,
    pub grow_every: u64,
    pub step: i32,
}

impl SchedulePolicy {
    pub fn new(init: PrecState, grow_every: u64, step: i32) -> Self {
        Self { init, grow_every, step }
    }
}

impl Policy for SchedulePolicy {
    fn name(&self) -> &'static str {
        "schedule"
    }

    fn init(&self) -> PrecState {
        self.init
    }

    fn update(&mut self, _current: PrecState, fb: &Feedback) -> PrecState {
        let grown = (fb.iter / self.grow_every) as i32 * self.step;
        let mut next = self.init;
        for class in [Class::Weight, Class::Act, Class::Grad] {
            let f = self.init.get(class);
            next.set(class, Format::new(f.il, f.fl + grown).clamped());
        }
        next
    }

    fn rounding(&self) -> Rounding {
        Rounding::Stochastic
    }

    /// Widen the schedule's base formats — `update` rebuilds from
    /// `self.init` every iteration, so widening only `current` would be
    /// silently undone one step later.
    fn escalate(&mut self, current: PrecState, class: Option<Class>) -> PrecState {
        let mut next = current;
        for c in [Class::Weight, Class::Act, Class::Grad] {
            if class.map(|t| t == c).unwrap_or(true) {
                let f = self.init.get(c);
                self.init.set(c, Format::new(f.il + 2, f.fl + 2).clamped());
                let cur = current.get(c);
                next.set(c, Format::new(cur.il + 2, cur.fl + 2).clamped());
            }
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ClassStats;

    fn fb(iter: u64) -> Feedback {
        let s = ClassStats::default();
        Feedback { iter, loss: 1.0, weights: s, acts: s, grads: s }
    }

    #[test]
    fn grows_on_schedule() {
        let init = PrecState::uniform(Format::new(4, 8));
        let mut p = SchedulePolicy::new(init, 100, 1);
        assert_eq!(p.update(init, &fb(0)).weights.fl, 8);
        assert_eq!(p.update(init, &fb(99)).weights.fl, 8);
        assert_eq!(p.update(init, &fb(100)).weights.fl, 9);
        assert_eq!(p.update(init, &fb(350)).weights.fl, 11);
    }

    #[test]
    fn clamps_at_max() {
        let init = PrecState::uniform(Format::new(4, 8));
        let mut p = SchedulePolicy::new(init, 1, 1);
        assert_eq!(p.update(init, &fb(1_000_000)).weights.fl, 24);
    }
}
