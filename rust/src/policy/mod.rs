//! Dynamic precision scaling controllers — the paper's contribution (and
//! every baseline it compares against in Table 1).
//!
//! Each iteration the trainer feeds the controller the quantization
//! feedback measured *inside* the AOT train step (per-site `E` and `R`,
//! aggregated per attribute class) plus the loss, and the controller emits
//! the `<IL, FL>` to use for weights, activations and gradients on the
//! *next* iteration.  Because precision is a runtime input of the HLO
//! artifact, switching costs nothing.
//!
//! | policy        | paper row (Table 1)    | bit-width | radix   | signal |
//! |---------------|------------------------|-----------|---------|--------|
//! | [`qedps`]     | **this paper**         | dynamic   | dynamic | E + R  |
//! | [`na`]        | Na & Mukhopadhyay [1]  | dynamic   | dynamic | loss convergence + R |
//! | [`courbariaux`]| Courbariaux et al.[2] | fixed     | dynamic | R      |
//! | [`fixed`]     | Gupta et al. [7]       | fixed     | fixed   | none   |
//! | [`float`]     | fp32 baseline          | 32        | —       | none   |
//! | [`schedule`]  | §1 "epoch-based" idea  | scheduled | fixed   | iter   |
//! | [`flexpoint`] | FlexPoint [9] (§5 wish)| fixed     | predictive | R EWMA |

pub mod courbariaux;
pub mod fixed;
pub mod flexpoint;
pub mod float;
pub mod na;
pub mod qedps;
pub mod schedule;

use crate::fixedpoint::Format;

pub use courbariaux::CourbariauxPolicy;
pub use fixed::FixedPolicy;
pub use flexpoint::FlexpointPolicy;
pub use float::FloatPolicy;
pub use na::NaPolicy;
pub use qedps::QedpsPolicy;
pub use schedule::SchedulePolicy;

/// The three attribute classes the paper scales independently.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    Weight,
    Act,
    Grad,
}

impl Class {
    pub fn from_str(s: &str) -> Option<Class> {
        match s {
            "weight" => Some(Class::Weight),
            "act" => Some(Class::Act),
            "grad" => Some(Class::Grad),
            _ => None,
        }
    }
}

/// Precision triple: one `<IL, FL>` per class (the paper's "Global"
/// granularity — one format per attribute class across all layers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrecState {
    pub weights: Format,
    pub acts: Format,
    pub grads: Format,
}

impl PrecState {
    pub fn uniform(fmt: Format) -> Self {
        Self { weights: fmt, acts: fmt, grads: fmt }
    }

    pub fn get(&self, c: Class) -> Format {
        match c {
            Class::Weight => self.weights,
            Class::Act => self.acts,
            Class::Grad => self.grads,
        }
    }

    pub fn set(&mut self, c: Class, fmt: Format) {
        match c {
            Class::Weight => self.weights = fmt,
            Class::Act => self.acts = fmt,
            Class::Grad => self.grads = fmt,
        }
    }

    /// Flattened into the artifact's `prec` input layout:
    /// `[ILw, FLw, ILa, FLa, ILg, FLg]`.
    pub fn to_vec(&self) -> [f32; 6] {
        [
            self.weights.il as f32,
            self.weights.fl as f32,
            self.acts.il as f32,
            self.acts.fl as f32,
            self.grads.il as f32,
            self.grads.fl as f32,
        ]
    }

    /// Inverse of [`Self::to_vec`]: rebuild the triple from the artifact's
    /// `prec` input layout (checkpoint state carries exactly this vector).
    pub fn from_vec(v: &[f32; 6]) -> Self {
        Self {
            weights: Format::new(v[0] as i32, v[1] as i32),
            acts: Format::new(v[2] as i32, v[3] as i32),
            grads: Format::new(v[4] as i32, v[5] as i32),
        }
    }

    /// Mean word length across the three classes (reporting convenience).
    pub fn mean_bits(&self) -> f64 {
        (self.weights.bits() + self.acts.bits() + self.grads.bits()) as f64 / 3.0
    }
}

/// Per-class aggregated feedback for one iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClassStats {
    pub e: f32,
    pub r: f32,
}

/// Everything a controller may condition on.
#[derive(Debug, Clone, Copy)]
pub struct Feedback {
    pub iter: u64,
    pub loss: f32,
    pub weights: ClassStats,
    pub acts: ClassStats,
    pub grads: ClassStats,
}

impl Feedback {
    pub fn class(&self, c: Class) -> ClassStats {
        match c {
            Class::Weight => self.weights,
            Class::Act => self.acts,
            Class::Grad => self.grads,
        }
    }
}

/// Which rounding-mode artifact a policy wants (Table 1 "Rounding" column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    Stochastic,
    Nearest,
    Float,
}

/// A dynamic precision scaling controller.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Initial precision (iteration 0 runs with this).
    fn init(&self) -> PrecState;

    /// Decide the precision for the next iteration.
    fn update(&mut self, current: PrecState, fb: &Feedback) -> PrecState;

    /// Rounding mode this scheme was defined with (selects the artifact).
    fn rounding(&self) -> Rounding {
        Rounding::Stochastic
    }

    /// Whether this policy runs the float (non-quantized) artifact.
    fn is_float(&self) -> bool {
        false
    }

    /// Whether the divergence watchdog may escalate this policy after a
    /// rollback.  Static baselines (`fixed`, `fixed13`, `float`) return
    /// false: their divergence behaviour *is* the experiment (the paper's
    /// §5 naive-13-bit demonstration), so the watchdog stays disarmed.
    fn can_escalate(&self) -> bool {
        true
    }

    /// Recovery hook: widen precision after a watchdog trip.  `class`
    /// names the overflowing attribute class when the trip identified one;
    /// `None` widens every class.  Policies that hold internal width state
    /// (target word lengths, fixed widths, schedules) override this so the
    /// widening sticks across subsequent `update` calls.
    fn escalate(&mut self, current: PrecState, class: Option<Class>) -> PrecState {
        let mut next = current;
        for c in [Class::Weight, Class::Act, Class::Grad] {
            if class.map(|t| t == c).unwrap_or(true) {
                let f = current.get(c);
                next.set(c, Format::new(f.il + 2, f.fl + 2).clamped());
            }
        }
        next
    }
}

/// How per-site stats collapse into the per-class scalars.
///
/// The paper's Algorithm 1 measures the *last layer* only; `Mean` across all
/// sites of a class is the robust default; `Max` is the conservative
/// variant.  The aggregation ablation bench compares all three.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggMode {
    Mean,
    Max,
    Last,
}

impl AggMode {
    pub fn from_str(s: &str) -> Option<AggMode> {
        match s {
            "mean" => Some(AggMode::Mean),
            "max" => Some(AggMode::Max),
            "last" => Some(AggMode::Last),
            _ => None,
        }
    }

    pub fn collapse(&self, values: &[f32]) -> f32 {
        if values.is_empty() {
            return 0.0;
        }
        match self {
            AggMode::Mean => values.iter().sum::<f32>() / values.len() as f32,
            AggMode::Max => values.iter().cloned().fold(f32::MIN, f32::max),
            AggMode::Last => *values.last().unwrap(),
        }
    }
}

/// Factory: build a policy by scheme name (the CLI/config surface).
pub fn make_policy(scheme: &str, opts: &PolicyOptions) -> anyhow::Result<Box<dyn Policy>> {
    Ok(match scheme {
        "qedps" => Box::new(QedpsPolicy::new(opts.e_max, opts.r_max, opts.init)),
        "na" => Box::new(NaPolicy::new(opts.init, opts.r_max)),
        "courbariaux" => Box::new(CourbariauxPolicy::new(
            opts.init.weights.bits(),
            opts.r_max,
            opts.init,
        )),
        "fixed" => Box::new(FixedPolicy::new(opts.init)),
        "fixed13" => Box::new(FixedPolicy::new(PrecState {
            // the paper's §5 divergence demonstration: 13-bit weights+acts
            weights: Format::new(4, 9),
            acts: Format::new(4, 9),
            grads: opts.init.grads,
        })),
        "gupta88" => Box::new(FixedPolicy::new(PrecState::uniform(Format::new(8, 8)))),
        "flexpoint" => Box::new(FlexpointPolicy::new(16, opts.init)),
        "float" => Box::new(FloatPolicy::new()),
        "schedule" => Box::new(SchedulePolicy::new(opts.init, 1000, 1)),
        other => anyhow::bail!("unknown scheme '{other}' (qedps|na|courbariaux|fixed|fixed13|gupta88|flexpoint|float|schedule)"),
    })
}

/// Tunables shared by the factory (paper defaults).
#[derive(Debug, Clone, Copy)]
pub struct PolicyOptions {
    /// `E_max`, the paper's quantization-error threshold (0.01% = 1e-4).
    pub e_max: f32,
    /// `R_max`, the overflow-rate threshold (0.01% = 1e-4).
    pub r_max: f32,
    /// Starting precision.
    pub init: PrecState,
}

impl Default for PolicyOptions {
    fn default() -> Self {
        Self {
            e_max: 1e-4,
            r_max: 1e-4,
            // Paper Fig. 3 trajectories start around 16 total bits; gradients
            // start wide (they "require the most precision").
            init: PrecState {
                weights: Format::new(2, 14),
                acts: Format::new(4, 12),
                grads: Format::new(2, 20),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prec_vec_layout() {
        let p = PrecState {
            weights: Format::new(1, 2),
            acts: Format::new(3, 4),
            grads: Format::new(5, 6),
        };
        assert_eq!(p.to_vec(), [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(p.mean_bits(), (3 + 7 + 11) as f64 / 3.0);
    }

    #[test]
    fn agg_modes() {
        let v = [0.1, 0.5, 0.2];
        assert!((AggMode::Mean.collapse(&v) - 0.26666668).abs() < 1e-6);
        assert_eq!(AggMode::Max.collapse(&v), 0.5);
        assert_eq!(AggMode::Last.collapse(&v), 0.2);
        assert_eq!(AggMode::Mean.collapse(&[]), 0.0);
    }

    #[test]
    fn factory_all_schemes() {
        let opts = PolicyOptions::default();
        for s in ["qedps", "na", "courbariaux", "fixed", "fixed13", "gupta88",
                  "flexpoint", "float", "schedule"] {
            let p = make_policy(s, &opts).unwrap();
            let st = p.init();
            assert!(st.weights.bits() >= 1, "{s}");
        }
        assert!(make_policy("nope", &opts).is_err());
    }

    #[test]
    fn fixed13_is_13_bits() {
        let p = make_policy("fixed13", &PolicyOptions::default()).unwrap();
        assert_eq!(p.init().weights.bits(), 13);
        assert_eq!(p.init().acts.bits(), 13);
    }

    #[test]
    fn static_baselines_refuse_escalation() {
        let opts = PolicyOptions::default();
        for s in ["fixed", "fixed13", "gupta88", "float"] {
            assert!(!make_policy(s, &opts).unwrap().can_escalate(), "{s}");
        }
        for s in ["qedps", "na", "courbariaux", "flexpoint", "schedule"] {
            assert!(make_policy(s, &opts).unwrap().can_escalate(), "{s}");
        }
    }

    #[test]
    fn escalation_widens_and_survives_update() {
        // For every escalatable scheme: escalate must widen the mean word
        // length, and one subsequent update must not shrink it back below
        // the pre-escalation width (the rollback would be pointless).
        let opts = PolicyOptions::default();
        let calm = Feedback {
            iter: 0,
            loss: 1.0,
            weights: ClassStats { e: 1e-6, r: 0.0 },
            acts: ClassStats { e: 1e-6, r: 0.0 },
            grads: ClassStats { e: 1e-6, r: 0.0 },
        };
        for s in ["qedps", "na", "courbariaux", "flexpoint", "schedule"] {
            let mut p = make_policy(s, &opts).unwrap();
            let before = p.init();
            let widened = p.escalate(before, None);
            assert!(
                widened.mean_bits() > before.mean_bits(),
                "{s}: {} -> {}",
                before.mean_bits(),
                widened.mean_bits()
            );
            let after = p.update(widened, &calm);
            assert!(
                after.mean_bits() + 1.0 > before.mean_bits(),
                "{s}: update undid escalation ({} -> {})",
                widened.mean_bits(),
                after.mean_bits()
            );
        }
    }

    #[test]
    fn class_targeted_escalation_leaves_others_alone() {
        let opts = PolicyOptions::default();
        let mut p = make_policy("qedps", &opts).unwrap();
        let before = p.init();
        let widened = p.escalate(before, Some(Class::Grad));
        assert!(widened.grads.bits() > before.grads.bits());
        assert_eq!(widened.weights, before.weights);
        assert_eq!(widened.acts, before.acts);
    }

    #[test]
    fn escalation_saturates_at_format_cap() {
        let opts = PolicyOptions::default();
        let mut p = make_policy("qedps", &opts).unwrap();
        let mut st = p.init();
        for _ in 0..40 {
            st = p.escalate(st, None);
        }
        for f in [st.weights, st.acts, st.grads] {
            assert!(f.il <= crate::fixedpoint::IL_RANGE.1);
            assert!(f.fl <= crate::fixedpoint::FL_RANGE.1);
        }
    }
}
