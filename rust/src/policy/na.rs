//! Na & Mukhopadhyay (ISLPED'16) convergence-based dynamic precision
//! scaling — the prior state of the art the paper beats.
//!
//! Their controller watches *training progress*, not quantization error:
//! start at a low target word length `tl`; whenever training stagnates
//! (windowed loss stops improving) or destabilizes (loss spikes / NaN),
//! raise `tl` by the unit step `s`, up to the hardware maximum `ml`.  The
//! radix (IL vs FL split) tracks overflow: grow IL on overflow, shrink it
//! when there is ample headroom.  Rounding is round-to-nearest in their
//! MAC, so this policy selects the `*_train_nearest` artifact.
//!
//! Parameters follow the ISLPED paper's shape (`ml`, `tl`, `s`); the
//! stagnation detector is the windowed-mean rule described in §III of
//! their paper (loss mean over the last window not improving by at least
//! `improve_eps` relative).

use super::{Class, Feedback, Policy, PrecState, Rounding};
use crate::fixedpoint::Format;

#[derive(Debug, Clone)]
pub struct NaPolicy {
    /// Maximum word length the hardware supports.
    pub ml: i32,
    /// Current target word length (per class, weights/acts share it).
    tl: [i32; 3],
    /// Unit bit step added on stagnation.
    pub step: i32,
    /// Overflow threshold steering the radix.
    pub r_max: f32,
    /// Loss window for the stagnation detector.
    window: usize,
    improve_eps: f32,
    losses: Vec<f32>,
    prev_window_mean: Option<f32>,
    init: PrecState,
}

impl NaPolicy {
    pub fn new(init: PrecState, r_max: f32) -> Self {
        Self {
            ml: 24,
            tl: [
                init.weights.bits(),
                init.acts.bits(),
                init.grads.bits(),
            ],
            step: 2,
            r_max,
            window: 50,
            improve_eps: 0.01,
            losses: Vec::new(),
            prev_window_mean: None,
            init,
        }
    }

    /// Stagnant or unstable? (drives the word-length escalation)
    fn training_needs_help(&mut self, loss: f32) -> bool {
        if !loss.is_finite() || loss > 100.0 {
            self.losses.clear();
            return true; // numerical instability
        }
        self.losses.push(loss);
        if self.losses.len() < self.window {
            return false;
        }
        let mean: f32 = self.losses.iter().sum::<f32>() / self.losses.len() as f32;
        self.losses.clear();
        let stagnant = match self.prev_window_mean {
            Some(prev) => mean > prev * (1.0 - self.improve_eps),
            None => false,
        };
        self.prev_window_mean = Some(mean);
        stagnant
    }

    fn split(&self, tl: i32, fmt: Format, r: f32) -> Format {
        // Radix: IL tracks overflow, FL takes the rest of the word.
        let il = if r > self.r_max {
            fmt.il + 1
        } else if r * 2.0 <= self.r_max {
            fmt.il - 1
        } else {
            fmt.il
        };
        let il = il.clamp(1, tl.max(2) - 1);
        Format::new(il, (tl - il).max(0)).clamped()
    }
}

impl Policy for NaPolicy {
    fn name(&self) -> &'static str {
        "na"
    }

    fn init(&self) -> PrecState {
        self.init
    }

    fn update(&mut self, current: PrecState, fb: &Feedback) -> PrecState {
        if self.training_needs_help(fb.loss) {
            for t in &mut self.tl {
                *t = (*t + self.step).min(self.ml);
            }
        }
        let mut next = current;
        for (i, class) in [Class::Weight, Class::Act, Class::Grad]
            .into_iter()
            .enumerate()
        {
            let s = fb.class(class);
            next.set(class, self.split(self.tl[i], current.get(class), s.r));
        }
        next
    }

    fn rounding(&self) -> Rounding {
        Rounding::Nearest
    }

    /// Raise the target word length(s) by the unit step (Na's own response
    /// to instability) and restart the stagnation detector.
    fn escalate(&mut self, current: PrecState, class: Option<Class>) -> PrecState {
        self.losses.clear();
        self.prev_window_mean = None;
        let mut next = current;
        for (i, c) in [Class::Weight, Class::Act, Class::Grad]
            .into_iter()
            .enumerate()
        {
            if class.map(|t| t == c).unwrap_or(true) {
                self.tl[i] = (self.tl[i] + self.step).min(self.ml);
                let f = current.get(c);
                let il = (f.il + 1).clamp(1, self.tl[i].max(2) - 1);
                next.set(c, Format::new(il, (self.tl[i] - il).max(0)).clamped());
            }
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ClassStats;

    fn fb(loss: f32, r: f32) -> Feedback {
        let s = ClassStats { e: 0.0, r };
        Feedback { iter: 0, loss, weights: s, acts: s, grads: s }
    }

    fn init() -> PrecState {
        PrecState::uniform(Format::new(4, 8))
    }

    #[test]
    fn word_length_constant_while_improving() {
        let mut p = NaPolicy::new(init(), 1e-4);
        let mut st = init();
        for i in 0..200 {
            // steadily improving loss
            st = p.update(st, &fb(2.0 / (1.0 + i as f32 * 0.1), 0.0));
        }
        assert_eq!(st.weights.bits(), 12);
    }

    #[test]
    fn escalates_on_stagnation() {
        let mut p = NaPolicy::new(init(), 1e-4);
        let mut st = init();
        for _ in 0..200 {
            st = p.update(st, &fb(1.5, 0.0)); // flat loss
        }
        assert!(st.weights.bits() > 12, "bits={}", st.weights.bits());
        assert!(st.weights.bits() <= 24);
    }

    #[test]
    fn escalates_on_instability() {
        let mut p = NaPolicy::new(init(), 1e-4);
        let st = p.update(init(), &fb(f32::NAN, 0.0));
        assert_eq!(st.weights.bits(), 14); // +step immediately
    }

    #[test]
    fn capped_at_ml() {
        let mut p = NaPolicy::new(init(), 1e-4);
        for _ in 0..100 {
            p.update(init(), &fb(f32::NAN, 0.0));
        }
        let st = p.update(init(), &fb(f32::NAN, 0.0));
        assert_eq!(st.weights.bits(), p.ml);
    }

    #[test]
    fn radix_tracks_overflow() {
        let mut p = NaPolicy::new(init(), 1e-4);
        // high overflow: IL should grow within the fixed word
        let st = p.update(init(), &fb(1.0, 0.5));
        assert_eq!(st.weights.il, 5);
        assert_eq!(st.weights.bits(), 12);
        // ample headroom: IL shrinks
        let st = p.update(init(), &fb(1.0, 0.0));
        assert_eq!(st.weights.il, 3);
        assert_eq!(st.weights.bits(), 12);
    }

    #[test]
    fn uses_nearest_rounding() {
        assert_eq!(NaPolicy::new(init(), 1e-4).rounding(), Rounding::Nearest);
    }
}
