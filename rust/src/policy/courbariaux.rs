//! Courbariaux, Bengio & David (2014): fixed bit-width, dynamic radix.
//!
//! The word length is constant (16 in their experiments); only the radix
//! moves, greedily favouring fractional precision:
//!
//! ```text
//! if R > R_max:        IL += 1  (FL -= 1)     // overflowing: widen range
//! else if 2R <= R_max: IL -= 1  (FL += 1)     // headroom: favour precision
//! else:                hold
//! ```

use super::{Class, Feedback, Policy, PrecState, Rounding};
use crate::fixedpoint::Format;

#[derive(Debug, Clone)]
pub struct CourbariauxPolicy {
    /// Constant word length (IL + FL).
    pub width: i32,
    pub r_max: f32,
    init: PrecState,
}

impl CourbariauxPolicy {
    pub fn new(width: i32, r_max: f32, init: PrecState) -> Self {
        // Re-split the init formats to the fixed width, keeping their IL.
        let fit = |f: Format| Format::new(f.il.min(width - 1).max(1),
                                          width - f.il.min(width - 1).max(1));
        Self {
            width,
            r_max,
            init: PrecState {
                weights: fit(init.weights),
                acts: fit(init.acts),
                grads: fit(init.grads),
            },
        }
    }

    fn shift(&self, fmt: Format, r: f32) -> Format {
        let il = if r > self.r_max {
            fmt.il + 1
        } else if 2.0 * r <= self.r_max {
            fmt.il - 1
        } else {
            fmt.il
        };
        let il = il.clamp(1, self.width - 1);
        Format::new(il, self.width - il)
    }
}

impl Policy for CourbariauxPolicy {
    fn name(&self) -> &'static str {
        "courbariaux"
    }

    fn init(&self) -> PrecState {
        self.init
    }

    fn update(&mut self, current: PrecState, fb: &Feedback) -> PrecState {
        let mut next = current;
        for class in [Class::Weight, Class::Act, Class::Grad] {
            next.set(class, self.shift(current.get(class), fb.class(class).r));
        }
        next
    }

    fn rounding(&self) -> Rounding {
        Rounding::Nearest
    }

    /// Courbariaux shares one word length across classes, so escalation
    /// grows the width itself (the radix keeps tracking overflow as usual).
    fn escalate(&mut self, current: PrecState, _class: Option<Class>) -> PrecState {
        self.width = (self.width + 2).min(crate::fixedpoint::IL_RANGE.1);
        let fit = |f: Format| {
            let il = (f.il + 1).clamp(1, self.width - 1);
            Format::new(il, self.width - il)
        };
        PrecState {
            weights: fit(current.weights),
            acts: fit(current.acts),
            grads: fit(current.grads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ClassStats;

    fn fb(r: f32) -> Feedback {
        let s = ClassStats { e: 0.0, r };
        Feedback { iter: 0, loss: 1.0, weights: s, acts: s, grads: s }
    }

    fn policy() -> CourbariauxPolicy {
        CourbariauxPolicy::new(16, 1e-4, PrecState::uniform(Format::new(8, 8)))
    }

    #[test]
    fn width_invariant_forever() {
        let mut p = policy();
        let mut st = p.init();
        let mut rng = crate::util::rng::Pcg32::seeded(1);
        for _ in 0..500 {
            st = p.update(st, &fb(rng.next_f32() * 1e-3));
            for c in [st.weights, st.acts, st.grads] {
                assert_eq!(c.bits(), 16);
            }
        }
    }

    #[test]
    fn overflow_shifts_radix_right() {
        let mut p = policy();
        let st = p.update(PrecState::uniform(Format::new(8, 8)), &fb(0.01));
        assert_eq!(st.weights, Format::new(9, 7));
    }

    #[test]
    fn headroom_shifts_radix_left() {
        let mut p = policy();
        let st = p.update(PrecState::uniform(Format::new(8, 8)), &fb(0.0));
        assert_eq!(st.weights, Format::new(7, 9));
    }

    #[test]
    fn dead_zone_holds() {
        // R_max/2 < R <= R_max: neither rule fires.
        let mut p = policy();
        let st = p.update(PrecState::uniform(Format::new(8, 8)), &fb(0.8e-4));
        assert_eq!(st.weights, Format::new(8, 8));
    }

    #[test]
    fn il_clamped_within_word() {
        let mut p = policy();
        let mut st = PrecState::uniform(Format::new(15, 1));
        for _ in 0..10 {
            st = p.update(st, &fb(1.0));
        }
        assert_eq!(st.weights, Format::new(15, 1));
        let mut st = PrecState::uniform(Format::new(1, 15));
        for _ in 0..10 {
            st = p.update(st, &fb(0.0));
        }
        assert_eq!(st.weights, Format::new(1, 15));
    }
}
