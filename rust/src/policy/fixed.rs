//! Static fixed-point training (Gupta et al. 2015): no scaling at all.
//!
//! Covers two paper rows: Gupta's `<8,8>`/`<10,6>`/`<14,2>` global fixed
//! formats, and the §5 "naive 13-bit diverges" demonstration (`fixed13` in
//! the factory = `<4,9>` weights/acts).

use super::{Class, Feedback, Policy, PrecState, Rounding};

#[derive(Debug, Clone)]
pub struct FixedPolicy {
    state: PrecState,
}

impl FixedPolicy {
    pub fn new(state: PrecState) -> Self {
        Self { state }
    }
}

impl Policy for FixedPolicy {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn init(&self) -> PrecState {
        self.state
    }

    fn update(&mut self, _current: PrecState, _fb: &Feedback) -> PrecState {
        self.state
    }

    fn rounding(&self) -> Rounding {
        Rounding::Stochastic
    }

    /// Divergence under a too-narrow static format is the §5 experiment —
    /// the watchdog must not rescue it.
    fn can_escalate(&self) -> bool {
        false
    }

    /// If escalated explicitly anyway, widen the stored format so the
    /// change survives `update` (which always returns `self.state`).
    fn escalate(&mut self, _current: PrecState, class: Option<Class>) -> PrecState {
        use crate::fixedpoint::Format;
        for c in [Class::Weight, Class::Act, Class::Grad] {
            if class.map(|t| t == c).unwrap_or(true) {
                let f = self.state.get(c);
                self.state.set(c, Format::new(f.il + 2, f.fl + 2).clamped());
            }
        }
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Format;
    use crate::policy::ClassStats;

    #[test]
    fn never_moves() {
        let init = PrecState::uniform(Format::new(8, 8));
        let mut p = FixedPolicy::new(init);
        let s = ClassStats { e: 1.0, r: 1.0 };
        let fb = Feedback { iter: 9, loss: 99.0, weights: s, acts: s, grads: s };
        assert_eq!(p.update(init, &fb), init);
    }
}
