//! **The paper's Algorithm 2**: quantization-error + overflow driven
//! scaling, applied to each attribute class every iteration.
//!
//! ```text
//! if R > R_max: IL += 1   else: IL -= 1
//! if E > E_max: FL += 1   else: FL -= 1
//! ```
//!
//! The scheme is deliberately aggressive (§2.2): it *shrinks* whenever the
//! signal is below threshold, so the bit-width constantly probes downward
//! and the thresholds (`E_max`, `R_max`, both 0.01% in the paper's
//! evaluation) are the knobs that stop it from starving training.
//! `IL`/`FL` are clamped to the legal emulation range (DESIGN.md §4).

use super::{Class, Feedback, Policy, PrecState, Rounding};
use crate::fixedpoint::Format;

#[derive(Debug, Clone)]
pub struct QedpsPolicy {
    pub e_max: f32,
    pub r_max: f32,
    init: PrecState,
}

impl QedpsPolicy {
    pub fn new(e_max: f32, r_max: f32, init: PrecState) -> Self {
        Self { e_max, r_max, init }
    }

    fn scale_one(&self, fmt: Format, e: f32, r: f32) -> Format {
        let il = if r > self.r_max { fmt.il + 1 } else { fmt.il - 1 };
        let fl = if e > self.e_max { fmt.fl + 1 } else { fmt.fl - 1 };
        Format::new(il, fl).clamped()
    }
}

impl Policy for QedpsPolicy {
    fn name(&self) -> &'static str {
        "qedps"
    }

    fn init(&self) -> PrecState {
        self.init
    }

    fn update(&mut self, current: PrecState, fb: &Feedback) -> PrecState {
        let mut next = current;
        for class in [Class::Weight, Class::Act, Class::Grad] {
            let s = fb.class(class);
            next.set(class, self.scale_one(current.get(class), s.e, s.r));
        }
        next
    }

    fn rounding(&self) -> Rounding {
        Rounding::Stochastic
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ClassStats;

    fn fb(e: f32, r: f32) -> Feedback {
        let s = ClassStats { e, r };
        Feedback { iter: 0, loss: 1.0, weights: s, acts: s, grads: s }
    }

    fn policy() -> QedpsPolicy {
        QedpsPolicy::new(1e-4, 1e-4, PrecState::uniform(Format::new(8, 8)))
    }

    #[test]
    fn grows_on_high_signals() {
        let mut p = policy();
        let next = p.update(PrecState::uniform(Format::new(8, 8)), &fb(1.0, 1.0));
        assert_eq!(next.weights, Format::new(9, 9));
        assert_eq!(next.acts, Format::new(9, 9));
        assert_eq!(next.grads, Format::new(9, 9));
    }

    #[test]
    fn shrinks_on_low_signals() {
        let mut p = policy();
        let next = p.update(PrecState::uniform(Format::new(8, 8)), &fb(0.0, 0.0));
        assert_eq!(next.weights, Format::new(7, 7));
    }

    #[test]
    fn mixed_signals_move_independently() {
        let mut p = policy();
        // high E, low R: FL up, IL down
        let next = p.update(PrecState::uniform(Format::new(8, 8)), &fb(1.0, 0.0));
        assert_eq!(next.acts, Format::new(7, 9));
        // low E, high R: FL down, IL up
        let next = p.update(PrecState::uniform(Format::new(8, 8)), &fb(0.0, 1.0));
        assert_eq!(next.acts, Format::new(9, 7));
    }

    #[test]
    fn threshold_is_strict_greater() {
        let mut p = policy();
        // exactly at threshold: treated as "low" -> shrink (Algorithm 2 uses >)
        let next = p.update(PrecState::uniform(Format::new(8, 8)),
                            &fb(1e-4, 1e-4));
        assert_eq!(next.weights, Format::new(7, 7));
    }

    #[test]
    fn clamped_at_bounds() {
        let mut p = policy();
        let lo = p.update(PrecState::uniform(Format::new(1, 0)), &fb(0.0, 0.0));
        assert_eq!(lo.weights, Format::new(1, 0));
        let hi = p.update(PrecState::uniform(Format::new(24, 24)), &fb(1.0, 1.0));
        assert_eq!(hi.weights, Format::new(24, 24));
    }

    #[test]
    fn per_class_independence() {
        let mut p = policy();
        let fb = Feedback {
            iter: 0,
            loss: 1.0,
            weights: ClassStats { e: 1.0, r: 1.0 },
            acts: ClassStats { e: 0.0, r: 0.0 },
            grads: ClassStats { e: 1.0, r: 0.0 },
        };
        let next = p.update(PrecState::uniform(Format::new(8, 8)), &fb);
        assert_eq!(next.weights, Format::new(9, 9));
        assert_eq!(next.acts, Format::new(7, 7));
        assert_eq!(next.grads, Format::new(7, 9));
    }

    /// Equilibrium behaviour: with a signal that flips across the threshold
    /// as FL moves, the controller oscillates around the knee instead of
    /// drifting (this is what produces the paper's plateau trajectories).
    #[test]
    fn oscillates_at_knee() {
        let mut p = policy();
        let mut st = PrecState::uniform(Format::new(8, 8));
        let mut seen = std::collections::HashSet::new();
        for i in 0..50 {
            // synthetic knee: error is high iff FL < 8
            let e = if st.acts.fl < 8 { 1.0 } else { 0.0 };
            st = p.update(st, &fb(e, 0.0));
            if i > 10 {
                seen.insert(st.acts.fl);
            }
        }
        assert!(seen.len() <= 3, "drifted: {seen:?}");
        assert!(seen.contains(&8) || seen.contains(&7));
    }
}
