//! Checkpointing: parameters + momentum as numpy-compatible `.npy` files,
//! run state as JSON.
//!
//! The xla crate's own `write_npy`/`write_npz` are broken upstream (they
//! `copy_raw_to::<u8>` an f32 literal, which its type check rejects), so
//! the npy *writer* lives here; reading uses the crate's working
//! `read_npy` path.
//!
//! Layout under the checkpoint dir:
//! ```text
//! <dir>/state-<iter>/p_<k>.npy     parameter tensors (manifest order)
//! <dir>/state-<iter>/m_<k>.npy     momentum tensors
//! <dir>/state-<iter>/state.json    iter, scheme, model, <IL,FL> triple
//! <dir>/LATEST                     iter number of the newest checkpoint
//! ```

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};
use xla::{FromRawBytes, Literal};

use crate::fixedpoint::Format;
use crate::policy::PrecState;
use crate::util::json::Json;

use super::Trainer;

/// Write one f32 literal as a numpy `.npy` (v1.0, C order, little-endian).
pub fn write_npy_f32(path: &Path, lit: &Literal) -> Result<()> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e}"))?;
    let dims: Vec<String> = shape.dims().iter().map(|d| d.to_string()).collect();
    let shape_str = match dims.len() {
        0 => "()".to_string(),
        1 => format!("({},)", dims[0]),
        _ => format!("({})", dims.join(", ")),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so magic(6)+ver(2)+len(2)+header is a multiple of 16, ending in \n
    let base = 6 + 2 + 2;
    let pad = 16 - (base + header.len() + 1) % 16;
    header.push_str(&" ".repeat(pad % 16));
    header.push('\n');

    let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(b"\x93NUMPY\x01\x00")?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for v in &data {
        f.write_all(&v.to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

pub fn save(dir: &str, trainer: &Trainer, iter: u64) -> Result<()> {
    let step_dir = Path::new(dir).join(format!("state-{iter}"));
    std::fs::create_dir_all(&step_dir)?;
    for (k, lit) in trainer.params().iter().enumerate() {
        write_npy_f32(&step_dir.join(format!("p_{k}.npy")), lit)?;
    }
    for (k, lit) in trainer.mom().iter().enumerate() {
        write_npy_f32(&step_dir.join(format!("m_{k}.npy")), lit)?;
    }
    let p = trainer.prec;
    let state = Json::obj(vec![
        ("iter", Json::Num(iter as f64)),
        ("model", Json::Str(trainer.cfg.model.clone())),
        ("scheme", Json::Str(trainer.policy.name().into())),
        ("n_params", Json::Num(trainer.params().len() as f64)),
        ("prec", Json::arr_f64(&p.to_vec().map(|v| v as f64))),
    ]);
    std::fs::write(step_dir.join("state.json"), state.to_string_pretty())?;
    std::fs::write(Path::new(dir).join("LATEST"), iter.to_string())?;
    crate::log_debug!("checkpoint: saved iter {iter} to {}", step_dir.display());
    Ok(())
}

/// Restore the newest checkpoint into `trainer`; returns the next iter.
pub fn load_latest(dir: &str, trainer: &mut Trainer) -> Result<u64> {
    let iter: u64 = std::fs::read_to_string(Path::new(dir).join("LATEST"))
        .context("no LATEST in checkpoint dir")?
        .trim()
        .parse()
        .context("bad LATEST")?;
    let step_dir = Path::new(dir).join(format!("state-{iter}"));
    let text = std::fs::read_to_string(step_dir.join("state.json"))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(
        j.get("model").as_str() == Some(trainer.cfg.model.as_str()),
        "checkpoint is for model {:?}, trainer has {}",
        j.get("model").as_str(),
        trainer.cfg.model
    );
    let n = j.get("n_params").as_usize().context("n_params")?;
    let mut params = Vec::with_capacity(n);
    let mut mom = Vec::with_capacity(n);
    for k in 0..n {
        params.push(
            Literal::read_npy(step_dir.join(format!("p_{k}.npy")), &())
                .map_err(|e| anyhow::anyhow!("p_{k}: {e}"))?,
        );
        mom.push(
            Literal::read_npy(step_dir.join(format!("m_{k}.npy")), &())
                .map_err(|e| anyhow::anyhow!("m_{k}: {e}"))?,
        );
    }
    let pv = j.get("prec");
    let f = |i: usize| -> Result<i32> {
        Ok(pv.at(i).as_f64().context("prec")? as i32)
    };
    let prec = PrecState {
        weights: Format::new(f(0)?, f(1)?),
        acts: Format::new(f(2)?, f(3)?),
        grads: Format::new(f(4)?, f(5)?),
    };
    trainer.restore(params, mom, prec);
    Ok(iter + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal_f32;

    #[test]
    fn npy_roundtrip_shapes() {
        let dir = std::env::temp_dir().join("qedps_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (data, shape) in [
            (vec![1.5f32, -2.25, 3.0, 0.0], vec![2usize, 2]),
            (vec![7.0f32], vec![] as Vec<usize>),
            ((0..30).map(|i| i as f32).collect(), vec![2, 3, 5]),
            (vec![0.25f32; 7], vec![7]),
        ] {
            let lit = literal_f32(&data, &shape).unwrap();
            let path = dir.join("t.npy");
            write_npy_f32(&path, &lit).unwrap();
            let back = Literal::read_npy(&path, &()).unwrap();
            assert_eq!(back.to_vec::<f32>().unwrap(), data, "shape {shape:?}");
            let got = back.array_shape().unwrap();
            let want: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            assert_eq!(got.dims(), want.as_slice());
        }
    }

    #[test]
    fn npy_is_numpy_compatible_header() {
        let dir = std::env::temp_dir().join("qedps_npy_hdr");
        std::fs::create_dir_all(&dir).unwrap();
        let lit = literal_f32(&[1.0, 2.0], &[2]).unwrap();
        let path = dir.join("h.npy");
        write_npy_f32(&path, &lit).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..6], b"\x93NUMPY");
        assert_eq!(bytes[6], 1);
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 16, 0, "header must align to 16");
        let header = std::str::from_utf8(&bytes[10..10 + hlen]).unwrap();
        assert!(header.contains("'descr': '<f4'"), "{header}");
        assert!(header.ends_with('\n'));
    }
}
