//! Crash-safe checkpointing: parameters + momentum as numpy-compatible
//! `.npy` files, run state as checksum-validated JSON.
//!
//! The xla crate's own `write_npy`/`write_npz` are broken upstream (they
//! `copy_raw_to::<u8>` an f32 literal, which its type check rejects), so
//! the npy *writer* lives here; reading uses the crate's working
//! `read_npy` path.
//!
//! Layout under the checkpoint dir:
//! ```text
//! <dir>/state-<iter>/p_<k>.npy     parameter tensors (manifest order)
//! <dir>/state-<iter>/m_<k>.npy     momentum tensors
//! <dir>/state-<iter>/state.json    iter, scheme, model, <IL,FL>, checksum
//! <dir>/LATEST                     iter number of the newest checkpoint
//! ```
//!
//! ## Torn-write safety
//!
//! A checkpoint is staged in `state-<iter>.tmp/`, every file is fsynced,
//! and the directory is renamed into place only when complete — a crash
//! mid-write leaves a `.tmp` directory that resume ignores.  `state.json`
//! carries an FNV-1a checksum over the tensor bytes (written last, inside
//! the staged dir), so even a checkpoint corrupted after the fact is
//! detected and skipped.  `LATEST` is likewise updated via temp+rename,
//! but it is only a hint: [`load_latest`] always resumes from the newest
//! checkpoint that *validates*, scanning past torn or corrupt ones.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};
use xla::{FromRawBytes, Literal};

use crate::policy::PrecState;
use crate::util::json::Json;

use super::Trainer;

/// Serialize one f32 literal as numpy `.npy` bytes (v1.0, C order,
/// little-endian).
pub fn npy_bytes_f32(lit: &Literal) -> Result<Vec<u8>> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e}"))?;
    let dims: Vec<String> = shape.dims().iter().map(|d| d.to_string()).collect();
    let shape_str = match dims.len() {
        0 => "()".to_string(),
        1 => format!("({},)", dims[0]),
        _ => format!("({})", dims.join(", ")),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so magic(6)+ver(2)+len(2)+header is a multiple of 16, ending in \n
    let base = 6 + 2 + 2;
    let pad = 16 - (base + header.len() + 1) % 16;
    header.push_str(&" ".repeat(pad % 16));
    header.push('\n');

    let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut out = Vec::with_capacity(base + header.len() + 4 * data.len());
    out.extend_from_slice(b"\x93NUMPY\x01\x00");
    out.extend_from_slice(&(header.len() as u16).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for v in &data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Write one f32 literal as a numpy `.npy` file.
pub fn write_npy_f32(path: &Path, lit: &Literal) -> Result<()> {
    std::fs::write(path, npy_bytes_f32(lit)?)?;
    Ok(())
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64, chainable (`h` starts at [`FNV_OFFSET`]).
fn fnv1a64(bytes: &[u8], mut h: u64) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Write + fsync one file (the building block of the atomic protocol).
fn write_synced(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {path:?}"))?;
    f.write_all(bytes)?;
    f.sync_all()?;
    Ok(())
}

/// The run metadata stored in `state.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub iter: u64,
    pub model: String,
    pub scheme: String,
    pub n_params: usize,
    pub prec: PrecState,
}

/// Checkpoint a trainer's current state (convenience wrapper over
/// [`save_state`]).
pub fn save(dir: &str, trainer: &Trainer, iter: u64) -> Result<()> {
    // with device-resident state this is the on-demand host download
    let (params, mom) = trainer.snapshot()?;
    save_state(
        dir,
        &trainer.cfg.model,
        trainer.policy.name(),
        trainer.prec,
        &params,
        &mom,
        iter,
    )
}

/// Atomically write one checkpoint: stage into `state-<iter>.tmp/`, fsync,
/// rename into place, then update `LATEST` via temp+rename.
pub fn save_state(
    dir: &str,
    model: &str,
    scheme: &str,
    prec: PrecState,
    params: &[Literal],
    mom: &[Literal],
    iter: u64,
) -> Result<()> {
    let dirp = Path::new(dir);
    std::fs::create_dir_all(dirp)?;
    let tmp = dirp.join(format!("state-{iter}.tmp"));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp)?;

    let mut hash = FNV_OFFSET;
    for (prefix, tensors) in [("p", params), ("m", mom)] {
        for (k, lit) in tensors.iter().enumerate() {
            let bytes = npy_bytes_f32(lit)?;
            hash = fnv1a64(&bytes, hash);
            write_synced(&tmp.join(format!("{prefix}_{k}.npy")), &bytes)?;
        }
    }
    let state = Json::obj(vec![
        ("iter", Json::Num(iter as f64)),
        ("model", Json::Str(model.into())),
        ("scheme", Json::Str(scheme.into())),
        ("n_params", Json::Num(params.len() as f64)),
        ("prec", Json::arr_f64(&prec.to_vec().map(|v| v as f64))),
        ("checksum", Json::Str(format!("{hash:016x}"))),
    ]);
    write_synced(&tmp.join("state.json"), state.to_string_pretty().as_bytes())?;

    let step_dir = dirp.join(format!("state-{iter}"));
    let _ = std::fs::remove_dir_all(&step_dir);
    std::fs::rename(&tmp, &step_dir)
        .with_context(|| format!("publishing {step_dir:?}"))?;
    // make the rename itself durable
    if let Ok(d) = std::fs::File::open(dirp) {
        let _ = d.sync_all();
    }

    let latest_tmp = dirp.join("LATEST.tmp");
    write_synced(&latest_tmp, iter.to_string().as_bytes())?;
    std::fs::rename(&latest_tmp, dirp.join("LATEST"))?;
    crate::log_debug!("checkpoint: saved iter {iter} to {}", step_dir.display());
    crate::telemetry::count("checkpoint.saves", 1);
    Ok(())
}

fn prec_from_json(j: &Json) -> Result<PrecState> {
    let pv = j.get("prec");
    let mut v = [0.0f32; 6];
    for (i, slot) in v.iter_mut().enumerate() {
        *slot = pv.at(i).as_f64().context("prec")? as f32;
    }
    Ok(PrecState::from_vec(&v))
}

/// Validate one `state-<iter>/` directory: parse `state.json`, confirm all
/// tensor files are present and (when the checkpoint carries a checksum)
/// that their bytes hash to it.  Pre-resilience checkpoints without a
/// checksum are accepted if every tensor file reads back.
pub fn validate(step_dir: &Path) -> Result<CheckpointMeta> {
    let text = std::fs::read_to_string(step_dir.join("state.json"))
        .with_context(|| format!("{step_dir:?}: no state.json"))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{step_dir:?}/state.json: {e}"))?;
    let meta = CheckpointMeta {
        iter: j.get("iter").as_f64().context("iter")? as u64,
        model: j.get("model").as_str().context("model")?.to_string(),
        scheme: j.get("scheme").as_str().unwrap_or("?").to_string(),
        n_params: j.get("n_params").as_usize().context("n_params")?,
        prec: prec_from_json(&j)?,
    };
    let mut hash = FNV_OFFSET;
    for prefix in ["p", "m"] {
        for k in 0..meta.n_params {
            let path = step_dir.join(format!("{prefix}_{k}.npy"));
            let bytes = std::fs::read(&path)
                .with_context(|| format!("{path:?}: missing tensor file"))?;
            hash = fnv1a64(&bytes, hash);
        }
    }
    if let Some(want) = j.get("checksum").as_str() {
        let got = format!("{hash:016x}");
        anyhow::ensure!(
            got == want,
            "{step_dir:?}: checksum mismatch ({got} != {want})"
        );
    }
    Ok(meta)
}

/// Iteration numbers of all non-staged `state-<n>` dirs under `dir`,
/// newest first (no validation — see [`latest_complete`]).
pub fn list_candidates(dir: &str) -> Vec<u64> {
    let mut iters: Vec<u64> = match std::fs::read_dir(dir) {
        Ok(entries) => entries
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().into_string().ok()?;
                name.strip_prefix("state-")?.parse().ok()
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    iters.sort_unstable_by(|a, b| b.cmp(a));
    iters
}

/// Keep-last-N garbage collection: delete all but the newest `keep`
/// `state-<n>` dirs (by iteration number; staging `.tmp` dirs are not
/// candidates and are left for the next save to reclaim).  `keep == 0`
/// disables pruning.  Returns the number of checkpoints removed.
pub fn gc(dir: &str, keep: u64) -> Result<usize> {
    if keep == 0 {
        return Ok(0);
    }
    let mut pruned = 0;
    for iter in list_candidates(dir).into_iter().skip(keep as usize) {
        let step_dir = Path::new(dir).join(format!("state-{iter}"));
        std::fs::remove_dir_all(&step_dir)
            .with_context(|| format!("pruning {step_dir:?}"))?;
        crate::log_debug!("checkpoint: pruned {}", step_dir.display());
        pruned += 1;
    }
    crate::telemetry::count("checkpoint.gc_pruned", pruned as u64);
    Ok(pruned)
}

/// The newest checkpoint under `dir` that passes [`validate`], skipping
/// (with a warning) any torn or corrupt ones.
pub fn latest_complete(dir: &str) -> Option<u64> {
    for iter in list_candidates(dir) {
        let step_dir = Path::new(dir).join(format!("state-{iter}"));
        match validate(&step_dir) {
            Ok(_) => return Some(iter),
            Err(e) => {
                crate::log_warn!("checkpoint: skipping {}: {e:#}", step_dir.display())
            }
        }
    }
    None
}

/// Read a validated checkpoint's tensors (standalone — no trainer needed).
pub fn load_state(
    dir: &str,
    iter: u64,
) -> Result<(CheckpointMeta, Vec<Literal>, Vec<Literal>)> {
    let step_dir: PathBuf = Path::new(dir).join(format!("state-{iter}"));
    let meta = validate(&step_dir)?;
    let read = |prefix: &str, k: usize| -> Result<Literal> {
        let path = step_dir.join(format!("{prefix}_{k}.npy"));
        Literal::read_npy(&path, &()).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))
    };
    let mut params = Vec::with_capacity(meta.n_params);
    let mut mom = Vec::with_capacity(meta.n_params);
    for k in 0..meta.n_params {
        params.push(read("p", k)?);
        mom.push(read("m", k)?);
    }
    Ok((meta, params, mom))
}

/// Restore the newest *complete* checkpoint into `trainer`; returns the
/// next iteration to run.  `LATEST` is only a hint — torn or corrupt
/// checkpoints (including leftover `state-<n>.tmp` staging dirs) are
/// skipped, so a crash mid-checkpoint never corrupts resume.
pub fn load_latest(dir: &str, trainer: &mut Trainer) -> Result<u64> {
    let iter = latest_complete(dir)
        .with_context(|| format!("no usable checkpoint under {dir}"))?;
    let (meta, params, mom) = load_state(dir, iter)?;
    anyhow::ensure!(
        meta.model == trainer.cfg.model,
        "checkpoint is for model {:?}, trainer has {}",
        meta.model,
        trainer.cfg.model
    );
    trainer.restore(params, mom, meta.prec)?;
    Ok(iter + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Format;
    use crate::runtime::literal_f32;

    fn fresh_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn tensors(scale: f32) -> Vec<Literal> {
        vec![
            literal_f32(&[1.0 * scale, -2.0 * scale], &[2]).unwrap(),
            literal_f32(&(0..6).map(|i| i as f32 * scale).collect::<Vec<_>>(), &[2, 3])
                .unwrap(),
        ]
    }

    fn prec() -> PrecState {
        PrecState {
            weights: Format::new(2, 14),
            acts: Format::new(4, 12),
            grads: Format::new(2, 20),
        }
    }

    #[test]
    fn npy_roundtrip_shapes() {
        let dir = fresh_dir("qedps_npy_test");
        for (data, shape) in [
            (vec![1.5f32, -2.25, 3.0, 0.0], vec![2usize, 2]),
            (vec![7.0f32], vec![] as Vec<usize>),
            ((0..30).map(|i| i as f32).collect(), vec![2, 3, 5]),
            (vec![0.25f32; 7], vec![7]),
        ] {
            let lit = literal_f32(&data, &shape).unwrap();
            let path = dir.join("t.npy");
            write_npy_f32(&path, &lit).unwrap();
            let back = Literal::read_npy(&path, &()).unwrap();
            assert_eq!(back.to_vec::<f32>().unwrap(), data, "shape {shape:?}");
            let got = back.array_shape().unwrap();
            let want: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            assert_eq!(got.dims(), want.as_slice());
        }
    }

    #[test]
    fn npy_is_numpy_compatible_header() {
        let dir = fresh_dir("qedps_npy_hdr");
        let lit = literal_f32(&[1.0, 2.0], &[2]).unwrap();
        let path = dir.join("h.npy");
        write_npy_f32(&path, &lit).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..6], b"\x93NUMPY");
        assert_eq!(bytes[6], 1);
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 16, 0, "header must align to 16");
        let header = std::str::from_utf8(&bytes[10..10 + hlen]).unwrap();
        assert!(header.contains("'descr': '<f4'"), "{header}");
        assert!(header.ends_with('\n'));
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = fresh_dir("qedps_ckpt_rt");
        let dir_s = dir.to_string_lossy().into_owned();
        let (params, mom) = (tensors(1.0), tensors(0.5));
        save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, 42).unwrap();

        assert_eq!(latest_complete(&dir_s), Some(42));
        let (meta, p2, m2) = load_state(&dir_s, 42).unwrap();
        assert_eq!(meta.iter, 42);
        assert_eq!(meta.model, "mlp");
        assert_eq!(meta.scheme, "qedps");
        assert_eq!(meta.prec, prec());
        for (a, b) in params.iter().zip(&p2) {
            assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        }
        for (a, b) in mom.iter().zip(&m2) {
            assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
        }
        // no staging leftovers
        assert!(!dir.join("state-42.tmp").exists());
        assert_eq!(
            std::fs::read_to_string(dir.join("LATEST")).unwrap().trim(),
            "42"
        );
    }

    #[test]
    fn resume_skips_torn_checkpoints() {
        let dir = fresh_dir("qedps_ckpt_torn");
        let dir_s = dir.to_string_lossy().into_owned();
        let (params, mom) = (tensors(1.0), tensors(0.5));
        save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, 5).unwrap();
        save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, 9).unwrap();

        // simulate a kill mid-checkpoint: newest dir lost its state.json
        std::fs::remove_file(dir.join("state-9/state.json")).unwrap();
        assert_eq!(latest_complete(&dir_s), Some(5));

        // a leftover staging dir (crash before rename) is never a candidate
        std::fs::create_dir_all(dir.join("state-12.tmp")).unwrap();
        std::fs::write(dir.join("state-12.tmp/p_0.npy"), b"partial").unwrap();
        assert_eq!(latest_complete(&dir_s), Some(5));
    }

    #[test]
    fn corrupt_tensor_bytes_fail_checksum() {
        let dir = fresh_dir("qedps_ckpt_sum");
        let dir_s = dir.to_string_lossy().into_owned();
        let (params, mom) = (tensors(1.0), tensors(0.5));
        save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, 7).unwrap();
        // flip one payload byte
        let p0 = dir.join("state-7/p_0.npy");
        let mut bytes = std::fs::read(&p0).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&p0, bytes).unwrap();
        assert!(validate(&dir.join("state-7")).is_err());
        assert_eq!(latest_complete(&dir_s), None);
    }

    #[test]
    fn missing_tensor_file_is_torn() {
        let dir = fresh_dir("qedps_ckpt_missing");
        let dir_s = dir.to_string_lossy().into_owned();
        let (params, mom) = (tensors(1.0), tensors(0.5));
        save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, 3).unwrap();
        std::fs::remove_file(dir.join("state-3/m_1.npy")).unwrap();
        assert!(validate(&dir.join("state-3")).is_err());
    }

    #[test]
    fn legacy_checkpoint_without_checksum_still_validates() {
        let dir = fresh_dir("qedps_ckpt_legacy");
        let dir_s = dir.to_string_lossy().into_owned();
        let (params, mom) = (tensors(1.0), tensors(0.5));
        save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, 8).unwrap();
        // rewrite state.json without the checksum field (pre-resilience layout)
        let sj = dir.join("state-8/state.json");
        let j = Json::parse(&std::fs::read_to_string(&sj).unwrap()).unwrap();
        let mut map = j.as_obj().unwrap().clone();
        map.remove("checksum");
        std::fs::write(&sj, Json::Obj(map).to_string_pretty()).unwrap();
        assert_eq!(validate(&dir.join("state-8")).unwrap().iter, 8);
    }

    #[test]
    fn gc_keeps_newest_n_and_spares_staging_dirs() {
        let dir = fresh_dir("qedps_ckpt_gc");
        let dir_s = dir.to_string_lossy().into_owned();
        let (params, mom) = (tensors(1.0), tensors(0.5));
        for iter in [3u64, 7, 11, 15, 19] {
            save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, iter).unwrap();
        }
        std::fs::create_dir_all(dir.join("state-21.tmp")).unwrap();

        // keep == 0 disables pruning entirely
        assert_eq!(gc(&dir_s, 0).unwrap(), 0);
        assert_eq!(list_candidates(&dir_s), vec![19, 15, 11, 7, 3]);

        assert_eq!(gc(&dir_s, 3).unwrap(), 2);
        assert_eq!(list_candidates(&dir_s), vec![19, 15, 11]);
        assert!(dir.join("state-21.tmp").exists(), "staging dir untouched");
        // survivors still validate and resume still works
        assert_eq!(latest_complete(&dir_s), Some(19));

        // idempotent once within budget
        assert_eq!(gc(&dir_s, 3).unwrap(), 0);
        // a missing dir is not an error
        assert_eq!(gc(&dir.join("nope").to_string_lossy(), 3).unwrap(), 0);
    }

    #[test]
    fn stale_latest_hint_does_not_break_resume() {
        let dir = fresh_dir("qedps_ckpt_stale");
        let dir_s = dir.to_string_lossy().into_owned();
        let (params, mom) = (tensors(1.0), tensors(0.5));
        save_state(&dir_s, "mlp", "qedps", prec(), &params, &mom, 4).unwrap();
        // LATEST points at a checkpoint that never finished
        std::fs::write(dir.join("LATEST"), "99").unwrap();
        assert_eq!(latest_complete(&dir_s), Some(4));
    }
}
