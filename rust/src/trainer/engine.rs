//! [`StepEngine`]: the compiled-executable hot path, and nothing else.
//!
//! The engine owns what one training run needs to *execute*: the train and
//! eval [`Executable`]s, the live parameter/momentum state, the host batch
//! buffers, and a set of **pre-pinned input literals**
//! ([`PinnedF32`]/[`PinnedI32`]) for batch x/y, the learning rate, the
//! stochastic-rounding seed, and the `<IL,FL>` precision triple — all
//! allocated once at construction and refilled in place each call, so
//! [`StepEngine::step`] constructs **zero** literals per iteration
//! ([`crate::runtime::literal_builds`] proves it).
//!
//! Parameter/momentum state lives in one of two modes ([`ParamState`]):
//!
//! - **Device** (default, `runtime.device_params = true`): the state stays
//!   resident as `PjRtBuffer`s ([`crate::runtime::DeviceState`]); each step
//!   executes via [`Executable::run_device`] and adopts its output buffers
//!   as the next step's inputs, so the steady-state loop performs **zero**
//!   host↔device state transfers ([`crate::runtime::host_transfers`] stays
//!   flat).  The train modules are lowered with `donate_argnums` over the
//!   state inputs, letting XLA alias the update in place.
//! - **Host** (fallback): the pre-device literal path — state uploads and
//!   downloads every step (`4 * n_params` counted transfers).  Selected by
//!   config, by a failed device upload, or automatically mid-run if the
//!   PJRT build returns tuple results ([`crate::runtime::DeviceRun`]
//!   `::Fetched`) — degraded transfer profile, identical numerics.
//!
//! Eval is transfer-free at steady state too: the first
//! [`StepEngine::evaluate`] call batches the test set once into an
//! [`EvalSet`] — per-batch pinned x/y literals with the tail-mask `valid`
//! counts precomputed (`eval.set_builds` / `eval.set_build` span) — and the
//! first device-executed pass uploads each batch's inputs once
//! (`device.h2d_input`).  Eval inputs are precision-independent (the eval
//! module quantizes in-graph from the `prec` pin), so every subsequent
//! device-path pass performs zero host-side batch prep, zero literal
//! builds, and zero input uploads; `repro bench eval` asserts exactly
//! that.  Host mode hoists its per-pass parameter upload to once per pass
//! (`device.h2d_state`) instead of once per batch inside every execute.
//! `runtime.eval_set = false` restores the legacy per-batch refill path
//! (identical numerics).
//!
//! Host copies of state happen only on demand: [`StepEngine::snapshot`]
//! (checkpoints, rollback), [`StepEngine::restore`]/`reinit`, and
//! fault-injection corruption.
//!
//! Policy decisions, history, and recovery live above this layer (the
//! [`super::Trainer`] facade and [`super::Session`]); the engine neither
//! reads feedback nor chooses precision — it runs whatever triple it is
//! handed and reports raw per-class `(E, R)` aggregates back.

use anyhow::{Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient};

use crate::config::ExperimentConfig;
use crate::data::{batcher::EvalBatcher, Batcher, Dataset};
use crate::policy::{AggMode, Class, PrecState, Rounding};
use crate::resilience::FaultInjector;
use crate::runtime::{
    clone_literal_f32, literal_f32, to_vec_f32, DeviceBuf, DeviceRun, DeviceState, Executable,
    PinnedF32, PinnedI32, Runtime,
};

/// What one executed step reports: scalars plus per-class `(E, R)`
/// aggregates, in `[weights, acts, grads]` order.
#[derive(Debug, Clone, Copy)]
pub struct RawStep {
    pub loss: f32,
    pub acc: f32,
    pub e: [f32; 3],
    pub r: [f32; 3],
}

/// Where the parameter/momentum state lives between steps.
enum ParamState {
    /// Host literals, re-uploaded every execution (legacy / fallback path).
    Host { params: Vec<Literal>, mom: Vec<Literal> },
    /// Device-resident buffers; step outputs become the next step's inputs.
    Device(DeviceState),
}

/// One precomputed eval batch: pinned host literals plus — filled lazily
/// the first time a device-exec pass touches it — resident device copies.
struct EvalBatch {
    x: PinnedF32,
    y: PinnedI32,
    /// How many leading entries are real examples (the rest are wrapped
    /// pads the accumulator masks off).
    valid: usize,
    x_dev: Option<DeviceBuf>,
    y_dev: Option<DeviceBuf>,
}

/// The whole test set, batched once (`eval.set_builds` / `eval.set_build`
/// span).  Eval inputs are precision-independent — the eval module
/// quantizes in-graph from the `prec` pin — so the cache stays valid for
/// the entire run; only a different dataset (fingerprint/length) or batch
/// size forces a rebuild.  After the first device-exec pass every batch
/// also holds resident x/y buffers, so steady-state eval passes perform
/// zero host-side batch prep and zero input uploads.
struct EvalSet {
    fp: u64,
    n: usize,
    batch: usize,
    batches: Vec<EvalBatch>,
}

/// One step's raw execution result, before state is written back.
enum StepExec {
    /// Per-output device buffers (state stays resident).
    DeviceOut(Vec<PjRtBuffer>),
    /// Host literals; `fallback` means a device-mode execution came back as
    /// a fetched tuple, so the engine must drop to host mode.
    HostOut { outs: Vec<Literal>, fallback: bool },
}

/// Exact streaming eval accumulator.
///
/// Per-example losses and correctness flags are summed **sequentially in
/// `f64`, in dataset order**, so the final `(mean loss, accuracy)` is
/// bit-identical for every eval batch size — a 25-example set scored at
/// batch 10 adds examples 0..10, 10..20, 20..25 in exactly the order a
/// batch-1 loop would.  [`EvalAccum::add_batch_sums`] is the legacy
/// whole-batch path for scalar artifacts (approximate on wrapped tails).
#[derive(Debug, Default, Clone)]
pub struct EvalAccum {
    loss_sum: f64,
    correct_sum: f64,
    total: usize,
}

impl EvalAccum {
    pub fn new() -> EvalAccum {
        EvalAccum::default()
    }

    /// Add per-example results (pad entries already sliced off by the
    /// caller: pass only the first `valid` of each batch).
    pub fn add_examples(&mut self, losses: &[f32], correct: &[f32]) {
        debug_assert_eq!(losses.len(), correct.len());
        for (&l, &c) in losses.iter().zip(correct) {
            self.loss_sum += l as f64;
            self.correct_sum += c as f64;
        }
        self.total += losses.len();
    }

    /// Legacy scalar-artifact path: whole-batch sums rescaled by
    /// `valid/batch`.  Exact only when `valid == batch`; wrapped pad
    /// entries otherwise still contribute to the batch sums.
    pub fn add_batch_sums(&mut self, loss_sum: f32, correct: f32, valid: usize, batch: usize) {
        let scale = valid as f64 / batch.max(1) as f64;
        self.loss_sum += loss_sum as f64 * scale;
        self.correct_sum += correct as f64 * scale;
        self.total += valid;
    }

    pub fn total(&self) -> usize {
        self.total
    }

    /// `(mean loss, accuracy)` over everything accumulated.
    pub fn finish(&self) -> (f32, f32) {
        let n = self.total.max(1) as f64;
        ((self.loss_sum / n) as f32, (self.correct_sum / n) as f32)
    }
}

/// Compiled executables + parameter state + pre-pinned input literals.
pub struct StepEngine {
    model: String,
    agg: AggMode,
    client: PjRtClient,
    exe_train: std::rc::Rc<Executable>,
    exe_eval: std::rc::Rc<Executable>,
    state: ParamState,
    n_params: usize,
    /// Manifest shapes of each parameter tensor (momenta are identical) —
    /// device mode has no host literals to read shapes from.
    param_shapes: Vec<Vec<usize>>,
    param_sizes: Vec<usize>,
    /// Eval module emits per-example `loss_vec`/`correct_vec` (exact tail
    /// masking) rather than legacy whole-batch scalars.
    eval_per_example: bool,
    x_shape: Vec<usize>,
    eval_x_shape: Vec<usize>,
    // reusable host-side batch buffers
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
    ex_buf: Vec<f32>,
    ey_buf: Vec<i32>,
    // pre-pinned device-input literals, refilled in place every call
    x_in: PinnedF32,
    y_in: PinnedI32,
    lr_in: PinnedF32,
    seed_in: PinnedF32,
    prec_in: PinnedF32,
    ex_in: PinnedF32,
    ey_in: PinnedI32,
    /// Last `<IL,FL>` six-vector written to `prec_in`; the literal is only
    /// refilled when the policy moves.  NaN-seeded so the first sync always
    /// writes.
    prec_cache: [f32; 6],
    /// Device copy of `prec_in`, re-uploaded only when the triple moves
    /// (cleared by `sync_prec`).  `None` in host mode until a hoisted eval
    /// pass uploads one.
    prec_dev: Option<DeviceBuf>,
    /// `runtime.eval_set`: use the precomputed [`EvalSet`] path (default);
    /// `false` selects the legacy per-batch refill path.
    use_eval_set: bool,
    /// The cached test set, built on the first `evaluate()` call.
    eval_set: Option<EvalSet>,
    /// Host mode tried to hoist its per-pass parameter upload and the
    /// device rejected it; stay on the per-batch literal path silently.
    host_eval_upload_broken: bool,
    /// Indices of each class's slots in the stat vectors.
    site_idx: [Vec<usize>; 3],
    evec_len: usize,
}

impl StepEngine {
    /// Compile (cached) and pin everything for `cfg.model`.
    ///
    /// `rounding` and `quantized_eval` are resolved by the caller (the
    /// policy owns those defaults; `force_rounding` overrides them).
    pub fn new(
        rt: &mut Runtime,
        cfg: &ExperimentConfig,
        rounding: Rounding,
        quantized_eval: bool,
    ) -> Result<StepEngine> {
        let train_name = crate::runtime::Manifest::train_module_name(&cfg.model, rounding);
        let eval_name = crate::runtime::Manifest::eval_module_name(&cfg.model, quantized_eval);
        let exe_train = rt.load(&train_name)?;
        let exe_eval = rt.load(&eval_name)?;
        let params = rt.load_params(&cfg.model)?;
        let mom = rt.zeros_like_params(&cfg.model)?;
        let n_params = params.len();
        let param_shapes: Vec<Vec<usize>> = rt
            .manifest
            .model(&cfg.model)?
            .params
            .iter()
            .map(|p| p.shape.clone())
            .collect();
        let param_sizes: Vec<usize> =
            param_shapes.iter().map(|s| s.iter().product()).collect();

        let spec = &exe_train.spec;
        let x_spec = &spec.inputs[spec.input_index("x")?];
        let x_shape = x_spec.shape.clone();
        let train_batch = x_shape[0];
        let espec = &exe_eval.spec;
        let eval_x_shape = espec.inputs[espec.input_index("x")?].shape.clone();
        let eval_batch = eval_x_shape[0];
        let eval_per_example = espec.outputs.iter().any(|t| t.name == "loss_vec");

        let client = rt.client.clone();
        let state = if cfg.device_params {
            match DeviceState::upload(&client, &params, &mom) {
                Ok(ds) => {
                    crate::log_debug!(
                        "engine: {train_name} state device-resident ({} tensors, donated={})",
                        2 * n_params,
                        spec.donated
                    );
                    ParamState::Device(ds)
                }
                Err(e) => {
                    crate::log_warn!(
                        "engine: device-resident state unavailable ({e}); \
                         falling back to host literals"
                    );
                    ParamState::Host { params, mom }
                }
            }
        } else {
            ParamState::Host { params, mom }
        };

        let site_idx = [
            spec.site_indices(Class::Weight),
            spec.site_indices(Class::Act),
            spec.site_indices(Class::Grad),
        ];
        let evec_len = spec.outputs[spec.output_index("evec")?].elems();

        Ok(StepEngine {
            x_buf: vec![0.0; x_shape.iter().product()],
            y_buf: vec![0; train_batch],
            ex_buf: vec![0.0; eval_x_shape.iter().product()],
            ey_buf: vec![0; eval_batch],
            x_in: PinnedF32::zeros(&x_shape)?,
            y_in: PinnedI32::zeros(&[train_batch])?,
            lr_in: PinnedF32::zeros(&[])?,
            seed_in: PinnedF32::zeros(&[])?,
            prec_in: PinnedF32::zeros(&[6])?,
            ex_in: PinnedF32::zeros(&eval_x_shape)?,
            ey_in: PinnedI32::zeros(&[eval_batch])?,
            prec_cache: [f32::NAN; 6],
            prec_dev: None,
            use_eval_set: cfg.eval_set,
            eval_set: None,
            host_eval_upload_broken: false,
            model: cfg.model.clone(),
            agg: cfg.agg,
            client,
            exe_train,
            exe_eval,
            state,
            n_params,
            param_shapes,
            param_sizes,
            eval_per_example,
            x_shape,
            eval_x_shape,
            site_idx,
            evec_len,
        })
    }

    pub fn train_batch_size(&self) -> usize {
        self.x_shape[0]
    }

    pub fn eval_batch_size(&self) -> usize {
        self.eval_x_shape[0]
    }

    /// Is the parameter/momentum state device-resident right now?
    pub fn device_resident(&self) -> bool {
        matches!(self.state, ParamState::Device(_))
    }

    /// Does eval mask pad entries exactly (per-example artifacts)?
    pub fn eval_exact(&self) -> bool {
        self.eval_per_example
    }

    /// Refill the shared precision literal iff the triple changed.
    fn sync_prec(&mut self, prec: &PrecState) -> Result<()> {
        let pv = prec.to_vec();
        if pv != self.prec_cache {
            self.prec_in.fill(&pv)?;
            self.prec_cache = pv;
            self.prec_dev = None; // device copy is stale
        }
        Ok(())
    }

    /// Make sure the device copy of the precision vector is current
    /// (no-op in host mode; re-uploads only after `sync_prec` moved it).
    fn ensure_prec_dev(&mut self) -> Result<()> {
        if matches!(self.state, ParamState::Device(_)) && self.prec_dev.is_none() {
            self.prec_dev = Some(DeviceBuf::from_literal(&self.client, self.prec_in.literal())?);
        }
        Ok(())
    }

    /// Aggregate a stat vector into a per-class value with the configured
    /// aggregation mode.
    fn collapse(&self, vec: &[f32], class: Class) -> f32 {
        let idx = &self.site_idx[match class {
            Class::Weight => 0,
            Class::Act => 1,
            Class::Grad => 2,
        }];
        let vals: Vec<f32> = idx.iter().map(|&i| vec[i]).collect();
        self.agg.collapse(&vals)
    }

    /// Run one training iteration from the pre-filled batch buffers at the
    /// given learning rate and precision.  Zero literal construction, and —
    /// in device mode — zero state transfers: last step's output buffers
    /// are this step's inputs.
    pub fn step(&mut self, iter: u64, lr: f32, prec: &PrecState) -> Result<RawStep> {
        let _step = crate::telemetry::span!("engine.step");
        crate::telemetry::count("engine.steps", 1);
        {
            let _s = crate::telemetry::span!("engine.refill");
            self.x_in.fill(&self.x_buf)?;
            self.y_in.fill(&self.y_buf)?;
            self.lr_in.set_scalar(lr)?;
            self.seed_in.set_scalar((iter + 1) as f32)?;
        }
        {
            let _s = crate::telemetry::span!("engine.quantize");
            self.sync_prec(prec)?;
            self.ensure_prec_dev()?;
        }

        let _exec_span = crate::telemetry::span!("engine.exec");
        let exec = match &self.state {
            ParamState::Device(ds) => {
                let x = DeviceBuf::from_literal(&self.client, self.x_in.literal())?;
                let y = DeviceBuf::from_literal(&self.client, self.y_in.literal())?;
                let lr_b = DeviceBuf::from_literal(&self.client, self.lr_in.literal())?;
                let seed = DeviceBuf::from_literal(&self.client, self.seed_in.literal())?;
                let prec_b = self.prec_dev.as_ref().expect("prec_dev ensured above");
                let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(2 * self.n_params + 5);
                inputs.extend(ds.input_buffers());
                inputs.push(x.buffer());
                inputs.push(y.buffer());
                inputs.push(lr_b.buffer());
                inputs.push(seed.buffer());
                inputs.push(prec_b.buffer());
                match self
                    .exe_train
                    .run_device(&inputs)
                    .with_context(|| format!("train step {iter}"))?
                {
                    DeviceRun::Resident(bufs) => StepExec::DeviceOut(bufs),
                    DeviceRun::Fetched(outs) => {
                        // state came back as host literals: 2P downloads
                        crate::runtime::note_host_transfers(2 * self.n_params as u64);
                        StepExec::HostOut { outs, fallback: true }
                    }
                }
            }
            ParamState::Host { params, mom } => {
                // literal path: 2P uploads inside execute + 2P downloads
                crate::runtime::note_host_transfers(4 * self.n_params as u64);
                let mut inputs: Vec<&Literal> = Vec::with_capacity(2 * self.n_params + 5);
                inputs.extend(params.iter());
                inputs.extend(mom.iter());
                inputs.push(self.x_in.literal());
                inputs.push(self.y_in.literal());
                inputs.push(self.lr_in.literal());
                inputs.push(self.seed_in.literal());
                inputs.push(self.prec_in.literal());
                let outs = self
                    .exe_train
                    .run(&inputs)
                    .with_context(|| format!("train step {iter}"))?;
                StepExec::HostOut { outs, fallback: false }
            }
        };
        drop(_exec_span);

        let _readback_span = crate::telemetry::span!("engine.readback");
        let (loss, acc, evec, rvec) = match exec {
            StepExec::DeviceOut(mut bufs) => {
                anyhow::ensure!(
                    bufs.len() == 2 * self.n_params + 4,
                    "train step output arity"
                );
                let stats = bufs.split_off(2 * self.n_params);
                let new_mom = bufs.split_off(self.n_params);
                let new_params = bufs;
                // scalar/stat readbacks are O(sites), not state transfers
                let fetch = |b: &PjRtBuffer| -> Result<Literal> {
                    b.to_literal_sync().map_err(|e| anyhow::anyhow!("{e}"))
                };
                let loss = fetch(&stats[0])?.get_first_element::<f32>()?;
                let acc = fetch(&stats[1])?.get_first_element::<f32>()?;
                let evec = to_vec_f32(&fetch(&stats[2])?)?;
                let rvec = to_vec_f32(&fetch(&stats[3])?)?;
                match &mut self.state {
                    ParamState::Device(ds) => ds.replace(new_params, new_mom),
                    ParamState::Host { .. } => unreachable!("device outputs in host mode"),
                }
                (loss, acc, evec, rvec)
            }
            StepExec::HostOut { outs, fallback } => {
                let mut it = outs.into_iter();
                let new_params: Vec<Literal> = (&mut it).take(self.n_params).collect();
                let new_mom: Vec<Literal> = (&mut it).take(self.n_params).collect();
                let rest: Vec<Literal> = it.collect();
                anyhow::ensure!(rest.len() == 4, "train step output arity");
                let loss = rest[0].get_first_element::<f32>()?;
                let acc = rest[1].get_first_element::<f32>()?;
                let evec = to_vec_f32(&rest[2])?;
                let rvec = to_vec_f32(&rest[3])?;
                if fallback {
                    crate::log_warn!(
                        "engine: PJRT returned a fetched tuple at step {iter}; \
                         dropping to host-literal state (numerics unchanged)"
                    );
                    self.prec_dev = None;
                    self.state = ParamState::Host { params: new_params, mom: new_mom };
                } else {
                    match &mut self.state {
                        ParamState::Host { params, mom } => {
                            *params = new_params;
                            *mom = new_mom;
                        }
                        ParamState::Device(_) => unreachable!("host outputs in device mode"),
                    }
                }
                (loss, acc, evec, rvec)
            }
        };
        drop(_readback_span);
        anyhow::ensure!(evec.len() == self.evec_len, "evec length");

        Ok(RawStep {
            loss,
            acc,
            e: [
                self.collapse(&evec, Class::Weight),
                self.collapse(&evec, Class::Act),
                self.collapse(&evec, Class::Grad),
            ],
            r: [
                self.collapse(&rvec, Class::Weight),
                self.collapse(&rvec, Class::Act),
                self.collapse(&rvec, Class::Grad),
            ],
        })
    }

    /// Evaluate on a full dataset at the given precision; returns
    /// (mean loss, accuracy).
    ///
    /// The default path ([`EvalSet`], `runtime.eval_set = true`) batches the
    /// test set once on the first call and — on device-executed passes —
    /// uploads each batch's inputs once, so steady-state eval passes perform
    /// zero literal construction, zero host-side batch prep, and zero input
    /// uploads.  `runtime.eval_set = false` selects the legacy per-batch
    /// refill path (identical numerics: both feed the same batches through
    /// the same module and [`EvalAccum`]).
    ///
    /// With per-example eval artifacts the tail batch is masked exactly:
    /// only the first `valid` outputs of each batch are accumulated, so a
    /// test set that is not a multiple of the eval batch scores identically
    /// to a batch-size-1 reference (see [`EvalAccum`]).  Legacy scalar
    /// artifacts fall back to the old `valid/batch` rescale and warn once.
    pub fn evaluate(&mut self, test: &Dataset, prec: &PrecState) -> Result<(f32, f32)> {
        self.sync_prec(prec)?;
        if self.use_eval_set {
            self.evaluate_set(test)
        } else {
            self.evaluate_refill(test)
        }
    }

    /// Precomputed-set eval pass: (re)build the [`EvalSet`] if the dataset
    /// changed, hoist per-pass device setup, then score every cached batch.
    fn evaluate_set(&mut self, test: &Dataset) -> Result<(f32, f32)> {
        let fp = test.fingerprint();
        let batch = self.eval_batch_size();
        let stale = match &self.eval_set {
            Some(s) => s.fp != fp || s.n != test.n || s.batch != batch,
            None => true,
        };
        if stale {
            let set = self.build_eval_set(test, fp)?;
            self.eval_set = Some(set);
        }
        let host_pbufs = self.prepare_device_eval()?;
        // Take the set out so the pass can cache device buffers into it
        // while `self` is borrowed for execution.
        let mut set = self.eval_set.take().expect("eval set built above");
        let result = self.eval_pass_set(&mut set, host_pbufs.as_deref());
        self.eval_set = Some(set);
        result
    }

    /// Batch the test set once: per-batch pinned x/y literals with the
    /// tail-mask `valid` count precomputed.  Device copies are attached
    /// lazily by the first device-executed pass.
    fn build_eval_set(&mut self, test: &Dataset, fp: u64) -> Result<EvalSet> {
        let _s = crate::telemetry::span!("eval.set_build");
        crate::telemetry::count("eval.set_builds", 1);
        let batch = self.eval_batch_size();
        let mut eb = EvalBatcher::new(test, batch);
        let mut batches = Vec::with_capacity(eb.num_batches());
        while let Some(valid) = eb.next_into(&mut self.ex_buf, &mut self.ey_buf) {
            let mut x = PinnedF32::zeros(&self.eval_x_shape)?;
            x.fill(&self.ex_buf)?;
            let mut y = PinnedI32::zeros(&[batch])?;
            y.fill(&self.ey_buf)?;
            batches.push(EvalBatch { x, y, valid, x_dev: None, y_dev: None });
        }
        Ok(EvalSet { fp, n: test.n, batch, batches })
    }

    /// Per-pass device setup for eval.
    ///
    /// Device mode: refresh the resident precision buffer if the triple
    /// moved; returns `None` (the state buffers are already on device).
    /// Host mode: hoist the parameter uploads to **once per pass** — the
    /// pre-hoist path re-uploaded all P parameters inside every per-batch
    /// execute — counted under `device.h2d_state`; returns the uploaded
    /// buffers, or `None` if device buffers are unavailable, in which case
    /// the per-batch literal path runs as before.
    fn prepare_device_eval(&mut self) -> Result<Option<Vec<DeviceBuf>>> {
        if matches!(self.state, ParamState::Device(_)) {
            self.ensure_prec_dev()?;
            return Ok(None);
        }
        if self.host_eval_upload_broken {
            return Ok(None);
        }
        let uploaded = (|| -> Result<(Vec<DeviceBuf>, Option<DeviceBuf>)> {
            let params = match &self.state {
                ParamState::Host { params, .. } => params,
                ParamState::Device(_) => unreachable!("handled above"),
            };
            let bufs = params
                .iter()
                .map(|l| DeviceBuf::from_state_literal(&self.client, l))
                .collect::<Result<Vec<_>>>()?;
            let prec = match self.prec_dev {
                None => Some(DeviceBuf::from_literal(&self.client, self.prec_in.literal())?),
                Some(_) => None,
            };
            Ok((bufs, prec))
        })();
        match uploaded {
            Ok((bufs, prec)) => {
                if let Some(p) = prec {
                    self.prec_dev = Some(p);
                }
                Ok(Some(bufs))
            }
            Err(e) => {
                crate::log_warn!(
                    "engine: per-pass eval parameter upload unavailable ({e}); \
                     staying on the per-batch literal path"
                );
                self.host_eval_upload_broken = true;
                Ok(None)
            }
        }
    }

    /// Score every batch of a prepared [`EvalSet`].  `host_pbufs` carries
    /// host mode's per-pass parameter uploads; device mode reads the
    /// resident state directly.
    fn eval_pass_set(
        &mut self,
        set: &mut EvalSet,
        host_pbufs: Option<&[DeviceBuf]>,
    ) -> Result<(f32, f32)> {
        let mut acc = EvalAccum::new();
        let mut warned = false;
        let device_exec = host_pbufs.is_some() || matches!(self.state, ParamState::Device(_));
        for b in set.batches.iter_mut() {
            let _s = crate::telemetry::span!("engine.eval_batch");
            crate::telemetry::count("eval.batches", 1);
            if device_exec && b.x_dev.is_none() {
                // First device-executed pass over this set: inputs become
                // resident here and every later pass uploads nothing.
                b.x_dev = Some(DeviceBuf::from_literal(&self.client, b.x.literal())?);
                b.y_dev = Some(DeviceBuf::from_literal(&self.client, b.y.literal())?);
            }
            let outs = match (&self.state, host_pbufs) {
                (ParamState::Device(ds), _) => {
                    let params: Vec<&PjRtBuffer> = ds.param_buffers().collect();
                    self.eval_exec_device(
                        &params,
                        b.x_dev.as_ref().expect("cached above").buffer(),
                        b.y_dev.as_ref().expect("cached above").buffer(),
                    )?
                }
                (ParamState::Host { .. }, Some(pb)) => {
                    let params: Vec<&PjRtBuffer> = pb.iter().map(|d| d.buffer()).collect();
                    self.eval_exec_device(
                        &params,
                        b.x_dev.as_ref().expect("cached above").buffer(),
                        b.y_dev.as_ref().expect("cached above").buffer(),
                    )?
                }
                (ParamState::Host { .. }, None) => {
                    self.eval_exec_literals(b.x.literal(), b.y.literal())?
                }
            };
            self.accumulate_eval(&outs, b.valid, &mut acc, &mut warned)?;
        }
        Ok(acc.finish())
    }

    /// Legacy eval pass (`runtime.eval_set = false`): stream the set through
    /// the shared `ex`/`ey` pins, refilled per batch.  Still benefits from
    /// the per-pass parameter hoist in host mode.
    fn evaluate_refill(&mut self, test: &Dataset) -> Result<(f32, f32)> {
        let batch = self.eval_batch_size();
        let host_pbufs = self.prepare_device_eval()?;
        let device_exec = host_pbufs.is_some() || matches!(self.state, ParamState::Device(_));
        let mut eb = EvalBatcher::new(test, batch);
        let mut acc = EvalAccum::new();
        let mut warned = false;
        while let Some(valid) = eb.next_into(&mut self.ex_buf, &mut self.ey_buf) {
            let _s = crate::telemetry::span!("engine.eval_batch");
            crate::telemetry::count("eval.batches", 1);
            self.ex_in.fill(&self.ex_buf)?;
            self.ey_in.fill(&self.ey_buf)?;
            let outs = if device_exec {
                let x = DeviceBuf::from_literal(&self.client, self.ex_in.literal())?;
                let y = DeviceBuf::from_literal(&self.client, self.ey_in.literal())?;
                match (&self.state, host_pbufs.as_deref()) {
                    (ParamState::Device(ds), _) => {
                        let params: Vec<&PjRtBuffer> = ds.param_buffers().collect();
                        self.eval_exec_device(&params, x.buffer(), y.buffer())?
                    }
                    (ParamState::Host { .. }, Some(pb)) => {
                        let params: Vec<&PjRtBuffer> = pb.iter().map(|d| d.buffer()).collect();
                        self.eval_exec_device(&params, x.buffer(), y.buffer())?
                    }
                    (ParamState::Host { .. }, None) => {
                        unreachable!("device_exec implies device buffers")
                    }
                }
            } else {
                self.eval_exec_literals(self.ex_in.literal(), self.ey_in.literal())?
            };
            self.accumulate_eval(&outs, valid, &mut acc, &mut warned)?;
        }
        Ok(acc.finish())
    }

    /// Execute the eval module against device inputs (`params` is either
    /// the resident state or this pass's hoisted uploads); returns host
    /// output literals.
    fn eval_exec_device(
        &self,
        params: &[&PjRtBuffer],
        x: &PjRtBuffer,
        y: &PjRtBuffer,
    ) -> Result<Vec<Literal>> {
        let prec_b = self.prec_dev.as_ref().expect("prec_dev prepared for device eval");
        let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(self.n_params + 3);
        inputs.extend_from_slice(params);
        inputs.push(x);
        inputs.push(y);
        inputs.push(prec_b.buffer());
        match self.exe_eval.run_device(&inputs)? {
            DeviceRun::Resident(bufs) => bufs
                .iter()
                .map(|b| b.to_literal_sync().map_err(|e| anyhow::anyhow!("{e}")))
                .collect(),
            DeviceRun::Fetched(outs) => Ok(outs),
        }
    }

    /// Host-literal eval execution (device buffers unavailable): the
    /// execute call re-uploads all P parameters internally, counted per
    /// batch as before the hoist.
    fn eval_exec_literals(&self, x: &Literal, y: &Literal) -> Result<Vec<Literal>> {
        let params = match &self.state {
            ParamState::Host { params, .. } => params,
            ParamState::Device(_) => unreachable!("literal eval path in device mode"),
        };
        crate::runtime::note_host_transfers(self.n_params as u64);
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.n_params + 3);
        inputs.extend(params.iter());
        inputs.push(x);
        inputs.push(y);
        inputs.push(self.prec_in.literal());
        self.exe_eval.run(&inputs)
    }

    /// Fold one batch's outputs into the accumulator: exact per-example
    /// tail masking when the artifacts provide it, the legacy
    /// `valid/batch` rescale (warned once) otherwise.
    fn accumulate_eval(
        &self,
        outs: &[Literal],
        valid: usize,
        acc: &mut EvalAccum,
        warned: &mut bool,
    ) -> Result<()> {
        let batch = self.eval_batch_size();
        if self.eval_per_example {
            let lv = to_vec_f32(&outs[0])?;
            let cv = to_vec_f32(&outs[1])?;
            anyhow::ensure!(
                lv.len() == batch && cv.len() == batch,
                "per-example eval output arity"
            );
            acc.add_examples(&lv[..valid], &cv[..valid]);
        } else {
            if valid != batch && !*warned {
                crate::log_warn!(
                    "engine: scalar eval artifacts rescale the wrapped tail \
                     ({valid}/{batch}) approximately; re-run `make artifacts` \
                     for exact per-example eval"
                );
                *warned = true;
            }
            acc.add_batch_sums(
                outs[0].get_first_element::<f32>()?,
                outs[1].get_first_element::<f32>()?,
                valid,
                batch,
            );
        }
        Ok(())
    }

    /// Copy the current parameters and momenta to host literals
    /// (checkpoint save, rollback snapshot, inspection).  Device mode
    /// downloads `2 * n_params` counted transfers; host mode deep-copies.
    pub fn snapshot(&self) -> Result<(Vec<Literal>, Vec<Literal>)> {
        match &self.state {
            ParamState::Host { params, mom } => {
                let cp = |v: &[Literal]| -> Result<Vec<Literal>> {
                    v.iter().map(clone_literal_f32).collect()
                };
                Ok((cp(params)?, cp(mom)?))
            }
            ParamState::Device(ds) => ds.snapshot(),
        }
    }

    /// Replace parameter/momentum state (checkpoint restore).  Device mode
    /// re-uploads the state (`2 * n_params` counted transfers).
    pub fn restore(&mut self, params: Vec<Literal>, mom: Vec<Literal>) -> Result<()> {
        anyhow::ensure!(
            params.len() == self.n_params && mom.len() == self.n_params,
            "restore: state arity"
        );
        match &mut self.state {
            ParamState::Host { params: p, mom: m } => {
                *p = params;
                *m = mom;
            }
            ParamState::Device(_) => {
                self.state = ParamState::Device(DeviceState::upload(&self.client, &params, &mom)?);
            }
        }
        Ok(())
    }

    /// Reset parameters and momentum to iteration-0 state.
    pub fn reinit(&mut self, rt: &mut Runtime) -> Result<()> {
        let params = rt.load_params(&self.model)?;
        let mom = rt.zeros_like_params(&self.model)?;
        self.restore(params, mom)
    }

    /// Flip one exponent bit in a stored tensor (fault injection):
    /// `Weight` corrupts a parameter, `Grad` corrupts a momentum slot.
    /// Returns a description of the corruption for the recovery log.
    pub fn corrupt_value(&mut self, class: Class, inj: &mut FaultInjector) -> Result<String> {
        let is_mom = matches!(class, Class::Grad);
        let (t, i, bit) = inj.flip_site(self.n_params, |k| self.param_sizes[k]);
        let mut data = match &self.state {
            ParamState::Host { params, mom } => {
                to_vec_f32(&(if is_mom { mom } else { params })[t])?
            }
            ParamState::Device(ds) => to_vec_f32(&ds.download(is_mom, t)?)?,
        };
        let old = data[i];
        data[i] = f32::from_bits(old.to_bits() ^ (1u32 << bit));
        let new = data[i];
        let lit = literal_f32(&data, &self.param_shapes[t])?;
        match &mut self.state {
            ParamState::Host { params, mom } => {
                (if is_mom { mom } else { params })[t] = lit;
            }
            ParamState::Device(ds) => ds.set(&self.client, is_mom, t, &lit)?,
        }
        Ok(format!(
            "flipped bit {bit} of {class:?} tensor {t} elem {i}: {old:e} -> {new:e}"
        ))
    }

    /// Fill the training batch buffers from a batcher.
    pub fn fill_batch(&mut self, b: &mut Batcher) {
        b.next_into(&mut self.x_buf, &mut self.y_buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_accum_batching_is_bit_identical() {
        // 25 synthetic per-example scores, scored at batch 10 vs batch 1:
        // the accumulator must produce bit-identical results.
        let losses: Vec<f32> = (0..25).map(|i| 0.1 + (i as f32) * 0.013).collect();
        let correct: Vec<f32> = (0..25).map(|i| (i % 3 == 0) as u32 as f32).collect();

        let mut b1 = EvalAccum::new();
        for i in 0..25 {
            b1.add_examples(&losses[i..i + 1], &correct[i..i + 1]);
        }
        let mut b10 = EvalAccum::new();
        for chunk in 0..3 {
            let lo = chunk * 10;
            let hi = (lo + 10).min(25);
            b10.add_examples(&losses[lo..hi], &correct[lo..hi]);
        }
        assert_eq!(b1.total(), 25);
        assert_eq!(b10.total(), 25);
        let (l1, a1) = b1.finish();
        let (l10, a10) = b10.finish();
        assert_eq!(l1.to_bits(), l10.to_bits(), "loss must be bit-identical");
        assert_eq!(a1.to_bits(), a10.to_bits(), "acc must be bit-identical");
    }

    #[test]
    fn eval_accum_legacy_rescale_is_approximate() {
        // The legacy path scales whole-batch sums by valid/batch: pad
        // entries still leak in.  Contrast with the exact masked path.
        let losses = [1.0f32, 2.0, 3.0, 4.0, 100.0]; // last entry is a pad
        let correct = [1.0f32, 0.0, 1.0, 0.0, 1.0];
        let valid = 4;
        let batch = 5;

        let mut exact = EvalAccum::new();
        exact.add_examples(&losses[..valid], &correct[..valid]);
        let (exact_loss, exact_acc) = exact.finish();
        assert_eq!(exact_loss, 2.5);
        assert_eq!(exact_acc, 0.5);

        let mut legacy = EvalAccum::new();
        let loss_sum: f32 = losses.iter().sum();
        let correct_sum: f32 = correct.iter().sum();
        legacy.add_batch_sums(loss_sum, correct_sum, valid, batch);
        let (legacy_loss, legacy_acc) = legacy.finish();
        assert!(
            (legacy_loss - exact_loss).abs() > 1.0,
            "pad contamination should be visible: {legacy_loss} vs {exact_loss}"
        );
        assert!(legacy_acc != exact_acc);
    }

    #[test]
    fn eval_accum_empty_is_safe() {
        let acc = EvalAccum::new();
        assert_eq!(acc.total(), 0);
        assert_eq!(acc.finish(), (0.0, 0.0));
    }
}
