//! [`StepEngine`]: the compiled-executable hot path, and nothing else.
//!
//! The engine owns what one training run needs to *execute*: the train and
//! eval [`Executable`]s, the live parameter/momentum literals, the host
//! batch buffers, and — the point of this layer — a set of **pre-pinned
//! input literals** ([`PinnedF32`]/[`PinnedI32`]) for batch x/y, the
//! learning rate, the stochastic-rounding seed, and the `<IL,FL>` precision
//! triple.  All of them are allocated once at construction and refilled in
//! place each call, so [`StepEngine::step`] constructs **zero** literals
//! per iteration (the precision literal is refilled only when the policy
//! actually moves).  `repro bench step` and the integration tests verify
//! this via [`crate::runtime::literal_builds`].
//!
//! Policy decisions, history, and recovery live above this layer (the
//! [`super::Trainer`] facade and [`super::Session`]); the engine neither
//! reads feedback nor chooses precision — it runs whatever triple it is
//! handed and reports raw per-class `(E, R)` aggregates back.

use anyhow::{Context, Result};
use xla::Literal;

use crate::config::ExperimentConfig;
use crate::data::{batcher::EvalBatcher, Batcher, Dataset};
use crate::policy::{AggMode, Class, PrecState, Rounding};
use crate::resilience::FaultInjector;
use crate::runtime::{literal_f32, Executable, PinnedF32, PinnedI32, Runtime};

/// What one executed step reports: scalars plus per-class `(E, R)`
/// aggregates, in `[weights, acts, grads]` order.
#[derive(Debug, Clone, Copy)]
pub struct RawStep {
    pub loss: f32,
    pub acc: f32,
    pub e: [f32; 3],
    pub r: [f32; 3],
}

/// Compiled executables + parameter state + pre-pinned input literals.
pub struct StepEngine {
    model: String,
    agg: AggMode,
    exe_train: std::rc::Rc<Executable>,
    exe_eval: std::rc::Rc<Executable>,
    params: Vec<Literal>,
    mom: Vec<Literal>,
    n_params: usize,
    x_shape: Vec<usize>,
    eval_x_shape: Vec<usize>,
    // reusable host-side batch buffers
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
    ex_buf: Vec<f32>,
    ey_buf: Vec<i32>,
    // pre-pinned device-input literals, refilled in place every call
    x_in: PinnedF32,
    y_in: PinnedI32,
    lr_in: PinnedF32,
    seed_in: PinnedF32,
    prec_in: PinnedF32,
    ex_in: PinnedF32,
    ey_in: PinnedI32,
    /// Last `<IL,FL>` six-vector written to `prec_in`; the literal is only
    /// refilled when the policy moves.  NaN-seeded so the first sync always
    /// writes.
    prec_cache: [f32; 6],
    /// Indices of each class's slots in the stat vectors.
    site_idx: [Vec<usize>; 3],
    evec_len: usize,
}

impl StepEngine {
    /// Compile (cached) and pin everything for `cfg.model`.
    ///
    /// `rounding` and `quantized_eval` are resolved by the caller (the
    /// policy owns those defaults; `force_rounding` overrides them).
    pub fn new(
        rt: &mut Runtime,
        cfg: &ExperimentConfig,
        rounding: Rounding,
        quantized_eval: bool,
    ) -> Result<StepEngine> {
        let train_name = crate::runtime::Manifest::train_module_name(&cfg.model, rounding);
        let eval_name = crate::runtime::Manifest::eval_module_name(&cfg.model, quantized_eval);
        let exe_train = rt.load(&train_name)?;
        let exe_eval = rt.load(&eval_name)?;
        let params = rt.load_params(&cfg.model)?;
        let mom = rt.zeros_like_params(&cfg.model)?;
        let n_params = params.len();

        let spec = &exe_train.spec;
        let x_spec = &spec.inputs[spec.input_index("x")?];
        let x_shape = x_spec.shape.clone();
        let train_batch = x_shape[0];
        let espec = &exe_eval.spec;
        let eval_x_shape = espec.inputs[espec.input_index("x")?].shape.clone();
        let eval_batch = eval_x_shape[0];

        let site_idx = [
            spec.site_indices(Class::Weight),
            spec.site_indices(Class::Act),
            spec.site_indices(Class::Grad),
        ];
        let evec_len = spec.outputs[spec.output_index("evec")?].elems();

        Ok(StepEngine {
            x_buf: vec![0.0; x_shape.iter().product()],
            y_buf: vec![0; train_batch],
            ex_buf: vec![0.0; eval_x_shape.iter().product()],
            ey_buf: vec![0; eval_batch],
            x_in: PinnedF32::zeros(&x_shape)?,
            y_in: PinnedI32::zeros(&[train_batch])?,
            lr_in: PinnedF32::zeros(&[])?,
            seed_in: PinnedF32::zeros(&[])?,
            prec_in: PinnedF32::zeros(&[6])?,
            ex_in: PinnedF32::zeros(&eval_x_shape)?,
            ey_in: PinnedI32::zeros(&[eval_batch])?,
            prec_cache: [f32::NAN; 6],
            model: cfg.model.clone(),
            agg: cfg.agg,
            exe_train,
            exe_eval,
            params,
            mom,
            n_params,
            x_shape,
            eval_x_shape,
            site_idx,
            evec_len,
        })
    }

    pub fn train_batch_size(&self) -> usize {
        self.x_shape[0]
    }

    pub fn eval_batch_size(&self) -> usize {
        self.eval_x_shape[0]
    }

    /// Refill the shared precision literal iff the triple changed.
    fn sync_prec(&mut self, prec: &PrecState) -> Result<()> {
        let pv = prec.to_vec();
        if pv != self.prec_cache {
            self.prec_in.fill(&pv)?;
            self.prec_cache = pv;
        }
        Ok(())
    }

    /// Aggregate a stat vector into a per-class value with the configured
    /// aggregation mode.
    fn collapse(&self, vec: &[f32], class: Class) -> f32 {
        let idx = &self.site_idx[match class {
            Class::Weight => 0,
            Class::Act => 1,
            Class::Grad => 2,
        }];
        let vals: Vec<f32> = idx.iter().map(|&i| vec[i]).collect();
        self.agg.collapse(&vals)
    }

    /// Run one training iteration from the pre-filled batch buffers at the
    /// given learning rate and precision.  Zero literal construction: every
    /// input is a refilled pinned literal.
    pub fn step(&mut self, iter: u64, lr: f32, prec: &PrecState) -> Result<RawStep> {
        self.x_in.fill(&self.x_buf)?;
        self.y_in.fill(&self.y_buf)?;
        self.lr_in.set_scalar(lr)?;
        self.seed_in.set_scalar((iter + 1) as f32)?;
        self.sync_prec(prec)?;

        let mut inputs: Vec<&Literal> = Vec::with_capacity(2 * self.n_params + 5);
        inputs.extend(self.params.iter());
        inputs.extend(self.mom.iter());
        inputs.push(self.x_in.literal());
        inputs.push(self.y_in.literal());
        inputs.push(self.lr_in.literal());
        inputs.push(self.seed_in.literal());
        inputs.push(self.prec_in.literal());

        let bufs = self
            .exe_train
            .run(&inputs)
            .with_context(|| format!("train step {iter}"))?;
        let mut outs = bufs.into_iter();
        let new_params: Vec<Literal> = (&mut outs).take(self.n_params).collect();
        let new_mom: Vec<Literal> = (&mut outs).take(self.n_params).collect();
        let rest: Vec<Literal> = outs.collect();
        anyhow::ensure!(rest.len() == 4, "train step output arity");
        let loss = rest[0].get_first_element::<f32>()?;
        let acc = rest[1].get_first_element::<f32>()?;
        let evec = crate::runtime::to_vec_f32(&rest[2])?;
        let rvec = crate::runtime::to_vec_f32(&rest[3])?;
        anyhow::ensure!(evec.len() == self.evec_len, "evec length");

        self.params = new_params;
        self.mom = new_mom;

        Ok(RawStep {
            loss,
            acc,
            e: [
                self.collapse(&evec, Class::Weight),
                self.collapse(&evec, Class::Act),
                self.collapse(&evec, Class::Grad),
            ],
            r: [
                self.collapse(&rvec, Class::Weight),
                self.collapse(&rvec, Class::Act),
                self.collapse(&rvec, Class::Grad),
            ],
        })
    }

    /// Evaluate on a full dataset at the given precision; returns
    /// (mean loss, accuracy).
    pub fn evaluate(&mut self, test: &Dataset, prec: &PrecState) -> Result<(f32, f32)> {
        let batch = self.eval_batch_size();
        self.sync_prec(prec)?;
        let mut eb = EvalBatcher::new(test, batch);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        while let Some(valid) = eb.next_into(&mut self.ex_buf, &mut self.ey_buf) {
            // keep shapes static; the generator sizes test sets to a
            // multiple of the eval batch, so valid == batch in practice.
            self.ex_in.fill(&self.ex_buf)?;
            self.ey_in.fill(&self.ey_buf)?;
            let mut inputs: Vec<&Literal> = Vec::with_capacity(self.n_params + 3);
            inputs.extend(self.params.iter());
            inputs.push(self.ex_in.literal());
            inputs.push(self.ey_in.literal());
            inputs.push(self.prec_in.literal());
            let outs = self.exe_eval.run(&inputs)?;
            let scale = valid as f64 / batch as f64;
            loss_sum += outs[0].get_first_element::<f32>()? as f64 * scale;
            correct += outs[1].get_first_element::<f32>()? as f64 * scale;
            total += valid;
        }
        Ok((
            (loss_sum / total.max(1) as f64) as f32,
            (correct / total.max(1) as f64) as f32,
        ))
    }

    /// Current parameters (for checkpointing / inspection).
    pub fn params(&self) -> &[Literal] {
        &self.params
    }

    pub fn mom(&self) -> &[Literal] {
        &self.mom
    }

    /// Replace parameter/momentum state (checkpoint restore).
    pub fn restore(&mut self, params: Vec<Literal>, mom: Vec<Literal>) {
        assert_eq!(params.len(), self.n_params);
        assert_eq!(mom.len(), self.n_params);
        self.params = params;
        self.mom = mom;
    }

    /// Reset parameters and momentum to iteration-0 state.
    pub fn reinit(&mut self, rt: &mut Runtime) -> Result<()> {
        self.params = rt.load_params(&self.model)?;
        self.mom = rt.zeros_like_params(&self.model)?;
        Ok(())
    }

    /// Flip one exponent bit in a stored tensor (fault injection):
    /// `Weight` corrupts a parameter, `Grad` corrupts a momentum slot.
    /// Returns a description of the corruption for the recovery log.
    pub fn corrupt_value(&mut self, class: Class, inj: &mut FaultInjector) -> Result<String> {
        let store = match class {
            Class::Grad => &mut self.mom,
            _ => &mut self.params,
        };
        let mut sizes = Vec::with_capacity(store.len());
        let mut shapes = Vec::with_capacity(store.len());
        for lit in store.iter() {
            let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            sizes.push(dims.iter().product::<usize>());
            shapes.push(dims);
        }
        let (t, i, bit) = inj.flip_site(store.len(), |k| sizes[k]);
        let mut data = crate::runtime::to_vec_f32(&store[t])?;
        let old = data[i];
        data[i] = f32::from_bits(old.to_bits() ^ (1u32 << bit));
        let new = data[i];
        store[t] = literal_f32(&data, &shapes[t])?;
        Ok(format!(
            "flipped bit {bit} of {class:?} tensor {t} elem {i}: {old:e} -> {new:e}"
        ))
    }

    /// Fill the training batch buffers from a batcher.
    pub fn fill_batch(&mut self, b: &mut Batcher) {
        b.next_into(&mut self.x_buf, &mut self.y_buf);
    }
}
