//! [`Session`]: one experiment's control loop — policy-driven training with
//! the resilience harness wrapped around the [`super::Trainer`] facade.
//!
//! The session owns everything *around* the hot path: the datasets, the
//! fault injector (shared with the [`Runtime`] so `read-fail` specs also
//! fire inside artifact/param loads), the divergence watchdog, the
//! rollback-with-escalation driver, metric recording, periodic eval, and
//! crash-safe checkpoints with keep-last-N garbage collection.  The actual
//! per-iteration execution is delegated to the trainer (and through it the
//! [`super::StepEngine`]), which keeps this module free of PJRT details.
//!
//! [`super::run_experiment`] is now a two-liner:
//! `Session::new(rt, cfg)?.run(rt)`.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::data::{Batcher, Dataset};
use crate::metrics::{EvalRecord, History, RecoveryEvent, TrainRecord};
use crate::resilience::{
    retry_with_backoff, FailureReport, FaultInjector, Watchdog, WatchdogConfig,
};
use crate::runtime::Runtime;
use crate::util::Stopwatch;

use super::{checkpoint, Trainer};

/// One experiment: config + data + trainer + recovery state.
///
/// Datasets are `Arc`-shared out of the process-wide [`crate::data::cache`]:
/// sweep workers running many schemes over the same source + sizes parse
/// MNIST once and point at one allocation.
pub struct Session {
    cfg: ExperimentConfig,
    trainer: Trainer,
    train: Arc<Dataset>,
    test: Arc<Dataset>,
    injector: Rc<RefCell<FaultInjector>>,
}

impl Session {
    /// Load data and build the trainer, with fault injection armed *before*
    /// any artifact/param read so `read-fail` specs cover those loads too.
    pub fn new(rt: &mut Runtime, cfg: &ExperimentConfig) -> Result<Session> {
        let mut cfg = cfg.clone();
        let eval_batch = rt.manifest.eval_batch;
        if !rt.manifest.eval_per_example(&cfg.model) {
            // legacy scalar eval artifacts rescale wrapped tail batches
            // approximately, so size the synthetic test set to a multiple of
            // the eval batch; per-example artifacts mask the tail exactly
            // and need no round-up.
            cfg.test_n = cfg.test_n.div_ceil(eval_batch) * eval_batch;
        }

        let injector = Rc::new(RefCell::new(FaultInjector::from_specs(
            &cfg.faults,
            cfg.fault_seed,
        )?));
        if injector.borrow().is_empty() {
            // a previous session on this runtime may have left faults armed
            rt.disarm_faults();
        } else {
            crate::log_warn!(
                "fault injection armed: {:?} (seed {})",
                cfg.faults,
                cfg.fault_seed
            );
            rt.arm_faults(injector.clone());
        }

        // The cache sits below the retry/injection wrapper: `read-fail`
        // specs still fire on every run's load call; only a successful
        // load is memoized and shared.
        let (train, test, source) = retry_with_backoff("dataset load", 3, 50, |_| {
            if let Some(e) = injector.borrow_mut().take_read_failure("dataset") {
                return Err(e);
            }
            Ok(crate::data::cache::load_default_cached(cfg.train_n, cfg.test_n))
        })?;
        crate::log_info!(
            "experiment: scheme={} model={} iters={} data={:?} (train={}, test={})",
            cfg.scheme, cfg.model, cfg.iters, source, train.n, test.n
        );
        let trainer = Trainer::new(rt, cfg.clone())?;
        Ok(Session { cfg, trainer, train, test, injector })
    }

    /// Drive the full run: loop, eval, metrics, checkpoints — wrapped in
    /// the divergence watchdog with rollback, precision escalation, bounded
    /// retries, and deterministic batch-stream replay.
    pub fn run(self, rt: &mut Runtime) -> Result<History> {
        let Session { cfg, mut trainer, train, test, injector } = self;
        // JSONL tracing is per-run: attach if configured, flush on every
        // exit path (the guard detaches on drop, including error returns).
        let _trace = crate::telemetry::TraceGuard::attach(cfg.trace_path.as_deref());
        // The registry is thread-accumulated; diff against this baseline so
        // the history carries only this run's telemetry.
        let telemetry_base = crate::telemetry::snapshot();
        let mut batcher = Batcher::new(&train, trainer.train_batch_size(), cfg.seed);
        let ckpt_dir = cfg.checkpoint_dir.clone();

        let mut iter: u64 = 0;
        if cfg.resume {
            let dir = ckpt_dir
                .as_deref()
                .context("resume=true requires a checkpoint dir")?;
            match checkpoint::load_latest(dir, &mut trainer) {
                Ok(next) => {
                    crate::log_info!("resume: continuing from iter {next}");
                    trainer.history.recovery.push(RecoveryEvent {
                        iter: next,
                        kind: "resume".into(),
                        detail: format!("resumed from checkpoint at iter {}", next - 1),
                        rollback_to: None,
                    });
                    skip_batches(&mut trainer, &mut batcher, next);
                    iter = next;
                }
                Err(e) => {
                    crate::log_warn!("resume: no usable checkpoint ({e:#}); starting fresh")
                }
            }
        }

        // The watchdog only arms for policies that can respond (static
        // baselines must keep their divergence — it *is* the §5 experiment).
        let armed = cfg.watchdog && trainer.policy.can_escalate();
        let mut watchdog = Watchdog::new(WatchdogConfig {
            loss_ratio: cfg.loss_explode_ratio as f32,
            warmup: cfg.watchdog_warmup,
            r_trip: cfg.overflow_trip as f32,
            r_window: cfg.overflow_window,
        });
        let mut retries: u64 = 0;

        while iter < cfg.iters {
            crate::telemetry::set_iter(iter);
            {
                let mut inj = injector.borrow_mut();
                if let Some(class) = inj.bitflip(iter) {
                    let detail = trainer.corrupt_value(class, &mut inj)?;
                    crate::log_warn!("iter {iter}: fault injected: {detail}");
                    trainer.history.recovery.push(RecoveryEvent {
                        iter,
                        kind: "fault_bitflip".into(),
                        detail,
                        rollback_to: None,
                    });
                }
            }

            trainer.fill_batch(&mut batcher);
            let t = Stopwatch::start();
            let mut out = trainer.step(iter)?;
            let step_ms = t.elapsed_ms();
            if let Some(forced) = injector.borrow_mut().loss_override(iter) {
                crate::log_warn!("iter {iter}: fault injected: loss forced to {forced}");
                trainer.history.recovery.push(RecoveryEvent {
                    iter,
                    kind: "fault_loss".into(),
                    detail: format!("loss forced to {forced}"),
                    rollback_to: None,
                });
                out.loss = forced;
                out.fb.loss = forced;
            }

            let last = iter + 1 == cfg.iters;
            if cfg.log_every > 0 && (iter % cfg.log_every == 0 || last) {
                trainer.history.train.push(TrainRecord {
                    iter,
                    loss: out.loss,
                    acc: out.acc,
                    lr: cfg.lr_at(iter),
                    prec: out.prec_used,
                    e: [out.fb.weights.e, out.fb.acts.e, out.fb.grads.e],
                    r: [out.fb.weights.r, out.fb.acts.r, out.fb.grads.r],
                    step_ms,
                });
                crate::log_debug!(
                    "iter {iter}: loss={:.4} acc={:.3} w={} a={} g={} ({step_ms:.1}ms)",
                    out.loss, out.acc, out.prec_used.weights, out.prec_used.acts,
                    out.prec_used.grads
                );
            }

            // Watchdog runs before eval/checkpoint so a poisoned state is
            // neither evaluated nor persisted as a rollback target.
            if armed {
                if let Some(trip) = watchdog.observe(&out.fb) {
                    retries += 1;
                    crate::log_warn!(
                        "iter {iter}: watchdog tripped: {trip} (recovery {retries}/{})",
                        cfg.max_recoveries
                    );
                    if retries > cfg.max_recoveries {
                        trainer.history.recovery.push(RecoveryEvent {
                            iter,
                            kind: "abort".into(),
                            detail: trip.to_string(),
                            rollback_to: None,
                        });
                        let report = FailureReport {
                            scheme: cfg.scheme.clone(),
                            model: cfg.model.clone(),
                            iter,
                            attempts: retries - 1,
                            reason: trip.to_string(),
                        };
                        let path = report.write(&cfg.out_dir, &trainer.history)?;
                        anyhow::bail!(
                            "run aborted after {} recovery attempts ({trip}); \
                             report: {}",
                            retries - 1,
                            path.display()
                        );
                    }
                    // Roll back: newest complete checkpoint, else a fresh
                    // initialization; then escalate precision and replay.
                    let _s = crate::telemetry::span!("session.rollback");
                    crate::telemetry::count("session.rollbacks", 1);
                    let restored = match ckpt_dir.as_deref() {
                        Some(d) => match checkpoint::load_latest(d, &mut trainer) {
                            Ok(next) => Some(next),
                            Err(e) => {
                                crate::log_warn!(
                                    "rollback: {e:#}; restarting from initialization"
                                );
                                None
                            }
                        },
                        None => None,
                    };
                    let resume_iter = match restored {
                        Some(next) => next,
                        None => {
                            trainer.reinit(rt)?;
                            0
                        }
                    };
                    trainer.prec = trainer.policy.escalate(trainer.prec, trip.class());
                    crate::log_info!(
                        "iter {iter}: rolled back to iter {resume_iter}; escalated \
                         to w={} a={} g={}",
                        trainer.prec.weights,
                        trainer.prec.acts,
                        trainer.prec.grads
                    );
                    trainer.history.recovery.push(RecoveryEvent {
                        iter,
                        kind: trip.kind().into(),
                        detail: trip.to_string(),
                        rollback_to: Some(resume_iter),
                    });
                    // records past the rollback point describe undone work
                    trainer.history.train.retain(|r| r.iter < resume_iter);
                    trainer.history.eval.retain(|r| r.iter < resume_iter);
                    batcher = Batcher::new(&train, trainer.train_batch_size(), cfg.seed);
                    skip_batches(&mut trainer, &mut batcher, resume_iter);
                    let backoff = cfg
                        .recovery_backoff
                        .saturating_mul(1u64 << (retries - 1).min(16));
                    watchdog.hold_until(resume_iter + backoff);
                    watchdog.reset_baseline();
                    iter = resume_iter;
                    continue;
                }
            } else if !out.loss.is_finite() {
                // static-format divergence (the §5 demonstration): record and
                // keep going — the figure needs the whole (diverged) curve
                crate::log_warn!(
                    "iter {iter}: loss is not finite ({} divergence)",
                    trainer.policy.name()
                );
            }

            if (cfg.eval_every > 0 && iter % cfg.eval_every == 0 && iter > 0) || last {
                let _s = crate::telemetry::span!("session.eval");
                let (tl, ta) = trainer.evaluate(&test)?;
                trainer.history.eval.push(EvalRecord {
                    iter,
                    test_loss: tl,
                    test_acc: ta,
                });
                crate::log_info!(
                    "iter {iter}: test_acc={ta:.4} test_loss={tl:.4} \
                     bits(w/a/g)={}/{}/{}",
                    out.prec_used.weights.bits(),
                    out.prec_used.acts.bits(),
                    out.prec_used.grads.bits()
                );
            }
            if let Some(dir) = &ckpt_dir {
                if cfg.checkpoint_every > 0
                    && iter > 0
                    && (iter % cfg.checkpoint_every == 0 || last)
                {
                    let _s = crate::telemetry::span!("session.checkpoint");
                    checkpoint::save(dir, &trainer, iter)?;
                    // GC never fails a healthy run — a prune error is noise
                    // compared to losing the training job.
                    match checkpoint::gc(dir, cfg.keep_checkpoints) {
                        Ok(n) if n > 0 => {
                            crate::log_debug!("checkpoint gc: pruned {n} old state dirs")
                        }
                        Ok(_) => {}
                        Err(e) => crate::log_warn!("checkpoint gc failed: {e:#}"),
                    }
                }
            }
            iter += 1;
        }
        trainer.history.telemetry = Some(crate::telemetry::snapshot().diff(&telemetry_base));
        Ok(trainer.history)
    }
}

/// Advance a fresh batch stream past `n` consumed batches — deterministic
/// replay after a resume or rollback (each iteration consumes exactly one
/// batch, so the stream position equals the iteration number).
fn skip_batches(trainer: &mut Trainer, batcher: &mut Batcher, n: u64) {
    for _ in 0..n {
        trainer.fill_batch(batcher);
    }
}
