//! The training loop: the L3 hot path.
//!
//! Each iteration:
//! 1. fill the batch buffers (no allocation),
//! 2. execute the AOT train step with the *current* `<IL,FL>` triple as a
//!    runtime input,
//! 3. read back loss/acc + the per-site `(E, R)` stat vectors,
//! 4. aggregate stats per attribute class and let the [`crate::policy`]
//!    controller re-decide the precision for the next iteration,
//! 5. record metrics; periodically evaluate on the test set and checkpoint.
//!
//! Python is never involved: the step is a compiled PJRT executable.
//!
//! ## Recovery (see [`crate::resilience`])
//!
//! [`run_experiment`] wraps the loop in a divergence watchdog.  When the
//! watchdog trips — and the policy can escalate ([`Policy::can_escalate`];
//! static baselines keep their divergence, it *is* the §5 experiment) —
//! the driver rolls back to the newest complete checkpoint (or a fresh
//! initialization when none exists), widens the precision through
//! [`Policy::escalate`], rewinds the batch stream deterministically, and
//! replays.  The retry budget is bounded; exhausting it writes a
//! structured failure report and aborts.

pub mod checkpoint;

use anyhow::{Context, Result};
use xla::Literal;

use crate::config::ExperimentConfig;
use crate::data::{batcher::EvalBatcher, Batcher, Dataset};
use crate::metrics::{EvalRecord, History, RecoveryEvent, TrainRecord};
use crate::policy::{make_policy, Class, ClassStats, Feedback, Policy, PrecState};
use crate::resilience::{
    retry_with_backoff, FailureReport, FaultInjector, Watchdog, WatchdogConfig,
};
use crate::runtime::{literal_f32, literal_i32, Executable, Runtime};
use crate::util::Stopwatch;

/// Owns one training run.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub policy: Box<dyn Policy>,
    pub prec: PrecState,
    exe_train: std::rc::Rc<Executable>,
    exe_eval: std::rc::Rc<Executable>,
    params: Vec<Literal>,
    mom: Vec<Literal>,
    n_params: usize,
    x_shape: Vec<usize>,
    eval_x_shape: Vec<usize>,
    // reusable host-side batch buffers
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
    ex_buf: Vec<f32>,
    ey_buf: Vec<i32>,
    pub history: History,
    /// Indices of each class's slots in the stat vectors.
    site_idx: [Vec<usize>; 3],
    evec_len: usize,
}

impl Trainer {
    pub fn new(rt: &mut Runtime, cfg: ExperimentConfig) -> Result<Trainer> {
        let policy = make_policy(&cfg.scheme, &cfg.policy_options())?;
        let rounding = match cfg.force_rounding.as_deref() {
            Some("stochastic") => crate::policy::Rounding::Stochastic,
            Some("nearest") => crate::policy::Rounding::Nearest,
            Some(other) => anyhow::bail!("force_rounding must be stochastic|nearest, got {other}"),
            None => policy.rounding(),
        };
        let train_name =
            crate::runtime::Manifest::train_module_name(&cfg.model, rounding);
        let eval_name =
            crate::runtime::Manifest::eval_module_name(&cfg.model, !policy.is_float());
        let exe_train = rt.load(&train_name)?;
        let exe_eval = rt.load(&eval_name)?;
        let params = rt.load_params(&cfg.model)?;
        let mom = rt.zeros_like_params(&cfg.model)?;
        let n_params = params.len();

        let spec = &exe_train.spec;
        let x_spec = &spec.inputs[spec.input_index("x")?];
        let x_shape = x_spec.shape.clone();
        let train_batch = x_shape[0];
        let espec = &exe_eval.spec;
        let eval_x_shape = espec.inputs[espec.input_index("x")?].shape.clone();
        let eval_batch = eval_x_shape[0];

        let site_idx = [
            spec.site_indices(Class::Weight),
            spec.site_indices(Class::Act),
            spec.site_indices(Class::Grad),
        ];
        let evec_len = spec.outputs[spec.output_index("evec")?].elems();

        let prec = policy.init();
        let history = History::new(policy.name(), &cfg.model);
        Ok(Trainer {
            x_buf: vec![0.0; x_shape.iter().product()],
            y_buf: vec![0; train_batch],
            ex_buf: vec![0.0; eval_x_shape.iter().product()],
            ey_buf: vec![0; eval_batch],
            cfg,
            policy,
            prec,
            exe_train,
            exe_eval,
            params,
            mom,
            n_params,
            x_shape,
            eval_x_shape,
            history,
            site_idx,
            evec_len,
        })
    }

    pub fn train_batch_size(&self) -> usize {
        self.x_shape[0]
    }

    pub fn eval_batch_size(&self) -> usize {
        self.eval_x_shape[0]
    }

    /// Aggregate a stat vector into per-class values with the configured
    /// aggregation mode.
    fn collapse(&self, vec: &[f32], class: Class) -> f32 {
        let idx = &self.site_idx[match class {
            Class::Weight => 0,
            Class::Act => 1,
            Class::Grad => 2,
        }];
        let vals: Vec<f32> = idx.iter().map(|&i| vec[i]).collect();
        self.cfg.agg.collapse(&vals)
    }

    /// Run one training iteration from pre-filled batch buffers.
    pub fn step(&mut self, iter: u64) -> Result<StepOutput> {
        let lr = self.cfg.lr_at(iter) as f32;
        let seed = (iter + 1) as f32;
        let prec_vec = self.prec.to_vec();

        let x = literal_f32(&self.x_buf, &self.x_shape)?;
        let y = literal_i32(&self.y_buf, &[self.y_buf.len()])?;
        let lr_l = Literal::scalar(lr);
        let seed_l = Literal::scalar(seed);
        let prec_l = literal_f32(&prec_vec, &[6])?;

        let mut inputs: Vec<&Literal> =
            Vec::with_capacity(2 * self.n_params + 5);
        inputs.extend(self.params.iter());
        inputs.extend(self.mom.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.push(&lr_l);
        inputs.push(&seed_l);
        inputs.push(&prec_l);

        let bufs = self
            .exe_train
            .run(&inputs)
            .with_context(|| format!("train step {iter}"))?;
        let mut outs = bufs.into_iter();
        let new_params: Vec<Literal> = (&mut outs).take(self.n_params).collect();
        let new_mom: Vec<Literal> = (&mut outs).take(self.n_params).collect();
        let rest: Vec<Literal> = outs.collect();
        anyhow::ensure!(rest.len() == 4, "train step output arity");
        let loss = rest[0].get_first_element::<f32>()?;
        let acc = rest[1].get_first_element::<f32>()?;
        let evec = crate::runtime::to_vec_f32(&rest[2])?;
        let rvec = crate::runtime::to_vec_f32(&rest[3])?;
        anyhow::ensure!(evec.len() == self.evec_len, "evec length");

        self.params = new_params;
        self.mom = new_mom;

        let fb = Feedback {
            iter,
            loss,
            weights: ClassStats {
                e: self.collapse(&evec, Class::Weight),
                r: self.collapse(&rvec, Class::Weight),
            },
            acts: ClassStats {
                e: self.collapse(&evec, Class::Act),
                r: self.collapse(&rvec, Class::Act),
            },
            grads: ClassStats {
                e: self.collapse(&evec, Class::Grad),
                r: self.collapse(&rvec, Class::Grad),
            },
        };
        let prec_used = self.prec;
        self.prec = self.policy.update(self.prec, &fb);
        Ok(StepOutput { loss, acc, fb, prec_used })
    }

    /// Evaluate on a full dataset; returns (mean loss, accuracy).
    pub fn evaluate(&mut self, test: &Dataset) -> Result<(f32, f32)> {
        let batch = self.eval_batch_size();
        let mut eb = EvalBatcher::new(test, batch);
        let prec_l = literal_f32(&self.prec.to_vec(), &[6])?;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        while let Some(valid) = eb.next_into(&mut self.ex_buf, &mut self.ey_buf) {
            // keep shapes static; the generator sizes test sets to a
            // multiple of the eval batch, so valid == batch in practice.
            let x = literal_f32(&self.ex_buf, &self.eval_x_shape)?;
            let y = literal_i32(&self.ey_buf, &[batch])?;
            let mut inputs: Vec<&Literal> = Vec::with_capacity(self.n_params + 3);
            inputs.extend(self.params.iter());
            inputs.push(&x);
            inputs.push(&y);
            inputs.push(&prec_l);
            let outs = self.exe_eval.run(&inputs)?;
            let scale = valid as f64 / batch as f64;
            loss_sum += outs[0].get_first_element::<f32>()? as f64 * scale;
            correct += outs[1].get_first_element::<f32>()? as f64 * scale;
            total += valid;
        }
        Ok((
            (loss_sum / total.max(1) as f64) as f32,
            (correct / total.max(1) as f64) as f32,
        ))
    }

    /// Current parameters (for checkpointing / inspection).
    pub fn params(&self) -> &[Literal] {
        &self.params
    }

    pub fn mom(&self) -> &[Literal] {
        &self.mom
    }

    pub fn restore(&mut self, params: Vec<Literal>, mom: Vec<Literal>, prec: PrecState) {
        assert_eq!(params.len(), self.n_params);
        assert_eq!(mom.len(), self.n_params);
        self.params = params;
        self.mom = mom;
        self.prec = prec;
    }

    /// Reset to iteration-0 state (rollback target when no checkpoint
    /// exists yet): fresh parameters, zero momentum, the policy's initial
    /// precision.
    pub fn reinit(&mut self, rt: &mut Runtime) -> Result<()> {
        self.params = rt.load_params(&self.cfg.model)?;
        self.mom = rt.zeros_like_params(&self.cfg.model)?;
        self.prec = self.policy.init();
        Ok(())
    }

    /// Flip one exponent bit in a stored tensor (fault injection):
    /// `Weight` corrupts a parameter, `Grad` corrupts a momentum slot.
    /// Returns a description of the corruption for the recovery log.
    pub fn corrupt_value(
        &mut self,
        class: Class,
        inj: &mut FaultInjector,
    ) -> Result<String> {
        let store = match class {
            Class::Grad => &mut self.mom,
            _ => &mut self.params,
        };
        let mut sizes = Vec::with_capacity(store.len());
        let mut shapes = Vec::with_capacity(store.len());
        for lit in store.iter() {
            let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("{e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            sizes.push(dims.iter().product::<usize>());
            shapes.push(dims);
        }
        let (t, i, bit) = inj.flip_site(store.len(), |k| sizes[k]);
        let mut data = crate::runtime::to_vec_f32(&store[t])?;
        let old = data[i];
        data[i] = f32::from_bits(old.to_bits() ^ (1u32 << bit));
        let new = data[i];
        store[t] = literal_f32(&data, &shapes[t])?;
        Ok(format!(
            "flipped bit {bit} of {class:?} tensor {t} elem {i}: {old:e} -> {new:e}"
        ))
    }

    /// Fill the training batch buffers from a batcher.
    pub fn fill_batch(&mut self, b: &mut Batcher) {
        b.next_into(&mut self.x_buf, &mut self.y_buf);
    }
}

/// What one step hands back to the driver.
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    pub loss: f32,
    pub acc: f32,
    pub fb: Feedback,
    /// The precision the step actually ran with (before the policy moved).
    pub prec_used: PrecState,
}

/// Advance a fresh batch stream past `n` consumed batches — deterministic
/// replay after a resume or rollback (each iteration consumes exactly one
/// batch, so the stream position equals the iteration number).
fn skip_batches(trainer: &mut Trainer, batcher: &mut Batcher, n: u64) {
    for _ in 0..n {
        trainer.fill_batch(batcher);
    }
}

/// Drive a full experiment: data, loop, eval, metrics, checkpoints —
/// wrapped in the resilience harness (divergence watchdog, rollback with
/// precision escalation, bounded retries, fault injection).
pub fn run_experiment(rt: &mut Runtime, cfg: &ExperimentConfig) -> Result<History> {
    let mut cfg = cfg.clone();
    let eval_batch = rt.manifest.eval_batch;
    // size the synthetic test set to a multiple of the eval batch
    cfg.test_n = cfg.test_n.div_ceil(eval_batch) * eval_batch;

    let mut injector = FaultInjector::from_specs(&cfg.faults, cfg.fault_seed)?;
    if !injector.is_empty() {
        crate::log_warn!(
            "fault injection armed: {:?} (seed {})",
            cfg.faults,
            cfg.fault_seed
        );
    }

    let (train, test, source) = retry_with_backoff("dataset load", 3, 50, |_| {
        if let Some(e) = injector.take_read_failure("dataset") {
            return Err(e);
        }
        Ok(crate::data::load_default(cfg.train_n, cfg.test_n))
    })?;
    crate::log_info!(
        "experiment: scheme={} model={} iters={} data={:?} (train={}, test={})",
        cfg.scheme, cfg.model, cfg.iters, source, train.n, test.n
    );
    let mut trainer = Trainer::new(rt, cfg.clone())?;
    let mut batcher = Batcher::new(&train, trainer.train_batch_size(), cfg.seed);
    let ckpt_dir = cfg.checkpoint_dir.clone();

    let mut iter: u64 = 0;
    if cfg.resume {
        let dir = ckpt_dir
            .as_deref()
            .context("resume=true requires a checkpoint dir")?;
        match checkpoint::load_latest(dir, &mut trainer) {
            Ok(next) => {
                crate::log_info!("resume: continuing from iter {next}");
                trainer.history.recovery.push(RecoveryEvent {
                    iter: next,
                    kind: "resume".into(),
                    detail: format!("resumed from checkpoint at iter {}", next - 1),
                    rollback_to: None,
                });
                skip_batches(&mut trainer, &mut batcher, next);
                iter = next;
            }
            Err(e) => {
                crate::log_warn!("resume: no usable checkpoint ({e:#}); starting fresh")
            }
        }
    }

    // The watchdog only arms for policies that can respond (static
    // baselines must keep their divergence — it *is* the §5 experiment).
    let armed = cfg.watchdog && trainer.policy.can_escalate();
    let mut watchdog = Watchdog::new(WatchdogConfig {
        loss_ratio: cfg.loss_explode_ratio as f32,
        warmup: cfg.watchdog_warmup,
        r_trip: cfg.overflow_trip as f32,
        r_window: cfg.overflow_window,
    });
    let mut retries: u64 = 0;

    while iter < cfg.iters {
        if let Some(class) = injector.bitflip(iter) {
            let detail = trainer.corrupt_value(class, &mut injector)?;
            crate::log_warn!("iter {iter}: fault injected: {detail}");
            trainer.history.recovery.push(RecoveryEvent {
                iter,
                kind: "fault_bitflip".into(),
                detail,
                rollback_to: None,
            });
        }

        trainer.fill_batch(&mut batcher);
        let t = Stopwatch::start();
        let mut out = trainer.step(iter)?;
        let step_ms = t.elapsed_ms();
        if let Some(forced) = injector.loss_override(iter) {
            crate::log_warn!("iter {iter}: fault injected: loss forced to {forced}");
            trainer.history.recovery.push(RecoveryEvent {
                iter,
                kind: "fault_loss".into(),
                detail: format!("loss forced to {forced}"),
                rollback_to: None,
            });
            out.loss = forced;
            out.fb.loss = forced;
        }

        let last = iter + 1 == cfg.iters;
        if cfg.log_every > 0 && (iter % cfg.log_every == 0 || last) {
            trainer.history.train.push(TrainRecord {
                iter,
                loss: out.loss,
                acc: out.acc,
                lr: cfg.lr_at(iter),
                prec: out.prec_used,
                e: [out.fb.weights.e, out.fb.acts.e, out.fb.grads.e],
                r: [out.fb.weights.r, out.fb.acts.r, out.fb.grads.r],
                step_ms,
            });
            crate::log_debug!(
                "iter {iter}: loss={:.4} acc={:.3} w={} a={} g={} ({step_ms:.1}ms)",
                out.loss, out.acc, out.prec_used.weights, out.prec_used.acts,
                out.prec_used.grads
            );
        }

        // Watchdog runs before eval/checkpoint so a poisoned state is
        // neither evaluated nor persisted as a rollback target.
        if armed {
            if let Some(trip) = watchdog.observe(&out.fb) {
                retries += 1;
                crate::log_warn!(
                    "iter {iter}: watchdog tripped: {trip} (recovery {retries}/{})",
                    cfg.max_recoveries
                );
                if retries > cfg.max_recoveries {
                    trainer.history.recovery.push(RecoveryEvent {
                        iter,
                        kind: "abort".into(),
                        detail: trip.to_string(),
                        rollback_to: None,
                    });
                    let report = FailureReport {
                        scheme: cfg.scheme.clone(),
                        model: cfg.model.clone(),
                        iter,
                        attempts: retries - 1,
                        reason: trip.to_string(),
                    };
                    let path = report.write(&cfg.out_dir, &trainer.history)?;
                    anyhow::bail!(
                        "run aborted after {} recovery attempts ({trip}); \
                         report: {}",
                        retries - 1,
                        path.display()
                    );
                }
                // Roll back: newest complete checkpoint, else a fresh
                // initialization; then escalate precision and replay.
                let restored = match ckpt_dir.as_deref() {
                    Some(d) => match checkpoint::load_latest(d, &mut trainer) {
                        Ok(next) => Some(next),
                        Err(e) => {
                            crate::log_warn!(
                                "rollback: {e:#}; restarting from initialization"
                            );
                            None
                        }
                    },
                    None => None,
                };
                let resume_iter = match restored {
                    Some(next) => next,
                    None => {
                        trainer.reinit(rt)?;
                        0
                    }
                };
                trainer.prec = trainer.policy.escalate(trainer.prec, trip.class());
                crate::log_info!(
                    "iter {iter}: rolled back to iter {resume_iter}; escalated \
                     to w={} a={} g={}",
                    trainer.prec.weights,
                    trainer.prec.acts,
                    trainer.prec.grads
                );
                trainer.history.recovery.push(RecoveryEvent {
                    iter,
                    kind: trip.kind().into(),
                    detail: trip.to_string(),
                    rollback_to: Some(resume_iter),
                });
                // records past the rollback point describe undone work
                trainer.history.train.retain(|r| r.iter < resume_iter);
                trainer.history.eval.retain(|r| r.iter < resume_iter);
                batcher = Batcher::new(&train, trainer.train_batch_size(), cfg.seed);
                skip_batches(&mut trainer, &mut batcher, resume_iter);
                let backoff = cfg
                    .recovery_backoff
                    .saturating_mul(1u64 << (retries - 1).min(16));
                watchdog.hold_until(resume_iter + backoff);
                watchdog.reset_baseline();
                iter = resume_iter;
                continue;
            }
        } else if !out.loss.is_finite() {
            // static-format divergence (the §5 demonstration): record and
            // keep going — the figure needs the whole (diverged) curve
            crate::log_warn!(
                "iter {iter}: loss is not finite ({} divergence)",
                trainer.policy.name()
            );
        }

        if (cfg.eval_every > 0 && iter % cfg.eval_every == 0 && iter > 0) || last {
            let (tl, ta) = trainer.evaluate(&test)?;
            trainer.history.eval.push(EvalRecord {
                iter,
                test_loss: tl,
                test_acc: ta,
            });
            crate::log_info!(
                "iter {iter}: test_acc={ta:.4} test_loss={tl:.4} \
                 bits(w/a/g)={}/{}/{}",
                out.prec_used.weights.bits(),
                out.prec_used.acts.bits(),
                out.prec_used.grads.bits()
            );
        }
        if let Some(dir) = &ckpt_dir {
            if cfg.checkpoint_every > 0
                && iter > 0
                && (iter % cfg.checkpoint_every == 0 || last)
            {
                checkpoint::save(dir, &trainer, iter)?;
            }
        }
        iter += 1;
    }
    Ok(trainer.history)
}
