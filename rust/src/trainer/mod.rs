//! Training: a layered engine/session architecture.
//!
//! The monolithic trainer is split into three layers:
//!
//! - [`StepEngine`] (`engine`): the L3 hot path.  Owns the compiled PJRT
//!   executables, the **device-resident** parameter/momentum buffers
//!   (donated step inputs alias to outputs; host-literal fallback for
//!   platforms without buffer support), host batch buffers, and
//!   **pre-pinned input literals** refilled in place each call — one
//!   training step performs zero per-iteration `Literal` construction and
//!   zero host↔device state transfers.
//! - [`Trainer`] (this module): a thin facade for API stability.  Binds an
//!   engine to a [`crate::policy`] controller: each `step` runs the engine
//!   at the current `<IL,FL>` triple, folds the raw `(E, R)` aggregates
//!   into a [`Feedback`], and lets the policy re-decide the precision for
//!   the next iteration.
//! - [`Session`] (`session`): one experiment's control loop — data, metric
//!   recording, periodic eval, checkpointing with keep-last-N GC, and the
//!   resilience driver (divergence watchdog, rollback with precision
//!   escalation, bounded retries, fault injection; see
//!   [`crate::resilience`]).
//!
//! [`run_experiment`] is the stable entry point:
//! `Session::new(rt, cfg)?.run(rt)`.

pub mod checkpoint;
pub mod engine;
pub mod session;

use anyhow::Result;
use xla::Literal;

use crate::config::ExperimentConfig;
use crate::data::{Batcher, Dataset};
use crate::metrics::History;
use crate::policy::{make_policy, Class, ClassStats, Feedback, Policy, PrecState};
use crate::resilience::FaultInjector;
use crate::runtime::Runtime;

pub use engine::{EvalAccum, RawStep, StepEngine};
pub use session::Session;

/// Owns one training run: a [`StepEngine`] plus the policy controller and
/// its recorded history.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    pub policy: Box<dyn Policy>,
    pub prec: PrecState,
    pub history: History,
    engine: StepEngine,
}

impl Trainer {
    pub fn new(rt: &mut Runtime, cfg: ExperimentConfig) -> Result<Trainer> {
        let policy = make_policy(&cfg.scheme, &cfg.policy_options())?;
        let rounding = match cfg.force_rounding.as_deref() {
            Some("stochastic") => crate::policy::Rounding::Stochastic,
            Some("nearest") => crate::policy::Rounding::Nearest,
            Some(other) => anyhow::bail!("force_rounding must be stochastic|nearest, got {other}"),
            None => policy.rounding(),
        };
        let engine = StepEngine::new(rt, &cfg, rounding, !policy.is_float())?;
        let prec = policy.init();
        let history = History::new(policy.name(), &cfg.model);
        Ok(Trainer { cfg, policy, prec, history, engine })
    }

    pub fn train_batch_size(&self) -> usize {
        self.engine.train_batch_size()
    }

    pub fn eval_batch_size(&self) -> usize {
        self.engine.eval_batch_size()
    }

    /// Run one training iteration from pre-filled batch buffers: execute at
    /// the current precision, then let the policy move it for the next
    /// iteration.
    pub fn step(&mut self, iter: u64) -> Result<StepOutput> {
        let lr = self.cfg.lr_at(iter) as f32;
        let prec_used = self.prec;
        let raw = self.engine.step(iter, lr, &prec_used)?;
        let fb = Feedback {
            iter,
            loss: raw.loss,
            weights: ClassStats { e: raw.e[0], r: raw.r[0] },
            acts: ClassStats { e: raw.e[1], r: raw.r[1] },
            grads: ClassStats { e: raw.e[2], r: raw.r[2] },
        };
        self.prec = self.policy.update(self.prec, &fb);
        Ok(StepOutput { loss: raw.loss, acc: raw.acc, fb, prec_used })
    }

    /// Evaluate on a full dataset; returns (mean loss, accuracy).
    pub fn evaluate(&mut self, test: &Dataset) -> Result<(f32, f32)> {
        let prec = self.prec;
        self.engine.evaluate(test, &prec)
    }

    /// Host copies of the current parameters and momenta (checkpointing /
    /// rollback snapshot / inspection).  With device-resident state this is
    /// the on-demand download; in host mode it deep-copies the literals.
    pub fn snapshot(&self) -> Result<(Vec<Literal>, Vec<Literal>)> {
        self.engine.snapshot()
    }

    /// Is the parameter/momentum state device-resident (zero steady-state
    /// host transfers)?
    pub fn device_resident(&self) -> bool {
        self.engine.device_resident()
    }

    /// Does eval mask wrapped tail batches exactly (per-example artifacts)?
    pub fn eval_exact(&self) -> bool {
        self.engine.eval_exact()
    }

    pub fn restore(
        &mut self,
        params: Vec<Literal>,
        mom: Vec<Literal>,
        prec: PrecState,
    ) -> Result<()> {
        self.engine.restore(params, mom)?;
        self.prec = prec;
        Ok(())
    }

    /// Reset to iteration-0 state (rollback target when no checkpoint
    /// exists yet): fresh parameters, zero momentum, the policy's initial
    /// precision.
    pub fn reinit(&mut self, rt: &mut Runtime) -> Result<()> {
        self.engine.reinit(rt)?;
        self.prec = self.policy.init();
        Ok(())
    }

    /// Flip one exponent bit in a stored tensor (fault injection):
    /// `Weight` corrupts a parameter, `Grad` corrupts a momentum slot.
    /// Returns a description of the corruption for the recovery log.
    pub fn corrupt_value(&mut self, class: Class, inj: &mut FaultInjector) -> Result<String> {
        self.engine.corrupt_value(class, inj)
    }

    /// Fill the training batch buffers from a batcher.
    pub fn fill_batch(&mut self, b: &mut Batcher) {
        self.engine.fill_batch(b);
    }
}

/// What one step hands back to the driver.
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    pub loss: f32,
    pub acc: f32,
    pub fb: Feedback,
    /// The precision the step actually ran with (before the policy moved).
    pub prec_used: PrecState,
}

/// Drive a full experiment: data, loop, eval, metrics, checkpoints —
/// wrapped in the resilience harness (divergence watchdog, rollback with
/// precision escalation, bounded retries, fault injection).
pub fn run_experiment(rt: &mut Runtime, cfg: &ExperimentConfig) -> Result<History> {
    Session::new(rt, cfg)?.run(rt)
}
