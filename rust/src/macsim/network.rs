//! Per-layer MAC counts, inferred from model parameter shapes.
//!
//! The inference covers the architecture family used in this repo (and the
//! paper): 4-d params are VALID stride-1 convs each followed by a 2x2
//! max-pool, 2-d params are fully-connected layers; 1-d params (biases)
//! contribute no MACs.  Spatial dims are tracked through the stack so conv
//! MAC counts are exact.

/// One multiply-bearing layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerCost {
    pub name: String,
    /// MACs for one forward pass at the given batch size.
    pub macs: u64,
}

/// Infer layer costs from (name, shape) parameter list.
///
/// `input_hw` is the spatial size of the network input; `batch` scales all
/// counts.  Conv shapes are HWIO (kh, kw, cin, cout), FC shapes (in, out).
pub fn layer_costs(
    params: &[(&str, Vec<usize>)],
    input_hw: (usize, usize),
    batch: usize,
) -> Vec<LayerCost> {
    let (mut h, mut w) = input_hw;
    let mut out = Vec::new();
    for (name, shape) in params {
        match shape.len() {
            4 => {
                let (kh, kw, cin, cout) = (shape[0], shape[1], shape[2], shape[3]);
                let oh = h - kh + 1;
                let ow = w - kw + 1;
                let macs = (batch * oh * ow * cout * cin * kh * kw) as u64;
                out.push(LayerCost { name: name.to_string(), macs });
                // conv is followed by 2x2 pool in this family
                h = oh / 2;
                w = ow / 2;
            }
            2 => {
                let macs = (batch * shape[0] * shape[1]) as u64;
                out.push(LayerCost { name: name.to_string(), macs });
            }
            _ => {} // bias
        }
    }
    out
}

/// Total MACs of one forward pass.
pub fn total_macs(layers: &[LayerCost]) -> u64 {
    layers.iter().map(|l| l.macs).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_counts_exact() {
        let layers = layer_costs(
            &[
                ("cw1", vec![5, 5, 1, 20]),
                ("cb1", vec![20]),
                ("cw2", vec![5, 5, 20, 50]),
                ("cb2", vec![50]),
                ("fw1", vec![800, 500]),
                ("fb1", vec![500]),
                ("fw2", vec![500, 10]),
                ("fb2", vec![10]),
            ],
            (28, 28),
            1,
        );
        assert_eq!(layers.len(), 4);
        // conv1: 24*24*20*1*25 = 288_000
        assert_eq!(layers[0].macs, 288_000);
        // conv2: input 12x12 -> out 8x8: 8*8*50*20*25 = 1_600_000
        assert_eq!(layers[1].macs, 1_600_000);
        assert_eq!(layers[2].macs, 400_000);
        assert_eq!(layers[3].macs, 5_000);
        assert_eq!(total_macs(&layers), 2_293_000);
    }

    #[test]
    fn batch_scales_linearly() {
        let p = [("w", vec![10usize, 4])];
        let a = layer_costs(&p, (28, 28), 1);
        let b = layer_costs(&p, (28, 28), 64);
        assert_eq!(b[0].macs, 64 * a[0].macs);
    }

    #[test]
    fn biases_free() {
        let layers = layer_costs(&[("b", vec![10usize])], (28, 28), 1);
        assert!(layers.is_empty());
    }
}
