//! Cycle model of Na & Mukhopadhyay's **flexible multiply-accumulate
//! unit** — the hardware that motivates the whole paper (§6: lower
//! bit-width ⇒ direct training speedup).
//!
//! We cannot fabricate their unit, so we model it (DESIGN.md substitution
//! #4): the flexible MAC decomposes a `wa x ww`-bit multiply into
//! `ceil(wa/g) * ceil(ww/g)` sub-multiplies on a `g x g` array (g = 8 in
//! their design) and retires a fixed number of sub-multiplies per cycle.
//! Accumulation is wide (48-bit) and free.  A 32x32 MAC therefore costs
//! 16 sub-ops while an 8x8 MAC costs 1 — the 16x ceiling their Table II
//! reports; real speedup follows the *measured bit-width trajectory* that
//! the DPS controller produces, which is exactly what `repro macsim`
//! computes.
//!
//! [`unit`] — the per-MAC cycle cost model (+ exact-arithmetic validation
//! against [`crate::fixedpoint::arith`]).
//! [`network`] — per-layer MAC counts inferred from model parameter shapes.

pub mod network;
pub mod unit;

pub use network::{layer_costs, LayerCost};
pub use unit::MacUnit;

use crate::policy::PrecState;

/// Cycles for one training iteration at a given precision state.
///
/// Forward multiplies activations by weights; backward multiplies upstream
/// gradients by weights (dX) and by activations (dW) — the standard 1:2
/// fwd:bwd MAC ratio.
pub fn iteration_cycles(unit: &MacUnit, layers: &[LayerCost], prec: &PrecState) -> u64 {
    let wa = prec.acts.bits() as u32;
    let ww = prec.weights.bits() as u32;
    let wg = prec.grads.bits() as u32;
    let mut cycles = 0u64;
    for l in layers {
        cycles += l.macs * unit.cycles_per_mac(wa, ww); // fwd
        cycles += l.macs * unit.cycles_per_mac(wg, ww); // bwd dX
        cycles += l.macs * unit.cycles_per_mac(wg, wa); // bwd dW
    }
    cycles
}

/// Speedup of a measured precision trajectory vs an all-32-bit baseline.
pub fn trajectory_speedup(
    unit: &MacUnit,
    layers: &[LayerCost],
    trajectory: &[PrecState],
) -> f64 {
    use crate::fixedpoint::Format;
    let f32_state = PrecState::uniform(Format::new(16, 16)); // 32-bit words
    let base = iteration_cycles(unit, layers, &f32_state) as f64
        * trajectory.len() as f64;
    let actual: f64 = trajectory
        .iter()
        .map(|p| iteration_cycles(unit, layers, p) as f64)
        .sum();
    base / actual.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Format;

    fn lenet_layers() -> Vec<LayerCost> {
        network::layer_costs(
            &[
                ("cw1", vec![5, 5, 1, 20]),
                ("cw2", vec![5, 5, 20, 50]),
                ("fw1", vec![800, 500]),
                ("fw2", vec![500, 10]),
            ],
            (28, 28),
            64,
        )
    }

    #[test]
    fn low_precision_is_faster() {
        let unit = MacUnit::default();
        let layers = lenet_layers();
        let wide = iteration_cycles(&unit, &layers,
                                    &PrecState::uniform(Format::new(16, 16)));
        let narrow = iteration_cycles(&unit, &layers,
                                      &PrecState::uniform(Format::new(4, 4)));
        assert!(narrow * 10 < wide, "narrow={narrow} wide={wide}");
    }

    #[test]
    fn speedup_of_constant_8bit_is_16x() {
        let unit = MacUnit::default();
        let layers = lenet_layers();
        let traj = vec![PrecState::uniform(Format::new(4, 4)); 10];
        let s = trajectory_speedup(&unit, &layers, &traj);
        assert!((s - 16.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn speedup_of_32bit_trajectory_is_1x() {
        let unit = MacUnit::default();
        let layers = lenet_layers();
        let traj = vec![PrecState::uniform(Format::new(16, 16)); 5];
        assert!((trajectory_speedup(&unit, &layers, &traj) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn mixed_precision_classes_priced_separately() {
        let unit = MacUnit::default();
        let layers = lenet_layers();
        // cheap acts/weights, expensive grads: bwd dominates
        let p = PrecState {
            weights: Format::new(4, 4),
            acts: Format::new(4, 4),
            grads: Format::new(12, 12),
        };
        let c = iteration_cycles(&unit, &layers, &p);
        let all8 = iteration_cycles(&unit, &layers,
                                    &PrecState::uniform(Format::new(4, 4)));
        assert!(c > all8);
    }
}
