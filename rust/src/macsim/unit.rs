//! Per-MAC cycle cost of the flexible multiplier-accumulator.

use crate::fixedpoint::arith::{Fixed, MacAccumulator};
use crate::fixedpoint::Format;

/// The flexible MAC unit: a grid of `granule x granule` sub-multipliers,
/// `throughput` sub-multiplies retired per cycle, wide accumulator.
#[derive(Debug, Clone)]
pub struct MacUnit {
    /// Sub-multiplier operand width in bits (8 in the ISLPED'16 design).
    pub granule: u32,
    /// Sub-multiplies retired per cycle (the unit's full 32x32 capacity:
    /// 16 granules => a 32x32 MAC takes 16/16 = 1... we normalize so that a
    /// full-width 32x32 multiply costs 16 cycles and an 8x8 costs 1, i.e.
    /// throughput = 1 granule/cycle per lane).
    pub throughput: u32,
}

impl Default for MacUnit {
    fn default() -> Self {
        Self { granule: 8, throughput: 1 }
    }
}

impl MacUnit {
    /// Cycles to multiply a `wa`-bit activation by a `ww`-bit weight and
    /// accumulate.  Sub-multiplies needed: ceil(wa/g) * ceil(ww/g).
    pub fn cycles_per_mac(&self, wa: u32, ww: u32) -> u64 {
        let ga = wa.max(1).div_ceil(self.granule) as u64;
        let gw = ww.max(1).div_ceil(self.granule) as u64;
        (ga * gw).div_ceil(self.throughput as u64)
    }

    /// Peak speedup of `w`-bit ops over 32-bit ops on this unit.
    pub fn speedup_vs_32(&self, w: u32) -> f64 {
        self.cycles_per_mac(32, 32) as f64 / self.cycles_per_mac(w, w) as f64
    }

    /// Execute a dot product *exactly as the hardware would* (integer
    /// sub-multiplies, wide accumulate) and report (value, cycles).  Used
    /// by tests to pin the cost model to real arithmetic.
    pub fn execute_dot(
        &self,
        a: &[f32],
        w: &[f32],
        fmt_a: Format,
        fmt_w: Format,
    ) -> (f64, u64) {
        assert_eq!(a.len(), w.len());
        let mut acc = MacAccumulator::new(fmt_a, fmt_w);
        let mut cycles = 0;
        for (&x, &y) in a.iter().zip(w) {
            acc.mac(Fixed::encode(x, fmt_a), Fixed::encode(y, fmt_w));
            cycles += self.cycles_per_mac(fmt_a.bits() as u32, fmt_w.bits() as u32);
        }
        (acc.value(), cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize::{quantize_slice, RoundMode};
    use crate::util::rng::Pcg32;

    #[test]
    fn cycle_table_matches_islped_shape() {
        let u = MacUnit::default();
        // (wa, ww) -> cycles
        assert_eq!(u.cycles_per_mac(8, 8), 1);
        assert_eq!(u.cycles_per_mac(16, 8), 2);
        assert_eq!(u.cycles_per_mac(16, 16), 4);
        assert_eq!(u.cycles_per_mac(32, 32), 16);
        assert_eq!(u.cycles_per_mac(9, 8), 2); // partial granule rounds up
        assert_eq!(u.cycles_per_mac(1, 1), 1);
    }

    #[test]
    fn speedup_table() {
        let u = MacUnit::default();
        assert_eq!(u.speedup_vs_32(8), 16.0);
        assert_eq!(u.speedup_vs_32(16), 4.0);
        assert_eq!(u.speedup_vs_32(32), 1.0);
    }

    #[test]
    fn execute_dot_matches_f64_and_prices_correctly() {
        let u = MacUnit::default();
        let fmt_a = Format::new(4, 6);
        let fmt_w = Format::new(2, 8);
        let mut rng = Pcg32::seeded(3);
        let raw_a: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        let raw_w: Vec<f32> = (0..128).map(|_| rng.normal() as f32 * 0.1).collect();
        let (qa, _) = quantize_slice(&raw_a, fmt_a, 1, RoundMode::Stochastic);
        let (qw, _) = quantize_slice(&raw_w, fmt_w, 2, RoundMode::Stochastic);
        let (val, cycles) = u.execute_dot(&qa, &qw, fmt_a, fmt_w);
        let f64dot: f64 = qa.iter().zip(&qw).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((val - f64dot).abs() < 1e-9);
        // <4,6> = 10 bits -> 2 granules; <2,8> = 10 bits -> 2 granules; 4 c/MAC
        assert_eq!(cycles, 128 * 4);
    }

    #[test]
    fn wider_throughput_scales_down_cycles() {
        let u = MacUnit { granule: 8, throughput: 4 };
        assert_eq!(u.cycles_per_mac(32, 32), 4);
        assert_eq!(u.cycles_per_mac(8, 8), 1); // floor at 1 cycle
    }
}
