//! `repro` — the qedps launcher.
//!
//! ```text
//! repro train    [--model M] [--scheme S] [--iters N] [--config F] [--set k=v]...
//! repro figures  --fig 3|4   [--jobs N] [--shard i/n]  regenerate paper figures
//! repro compare  [--schemes a,b,c] [--jobs N] [--shard i/n]  Table-1 head-to-head
//! repro compare merge <files...>                    join compare.shard-*.json slices
//! repro rounding-ab [--jobs N] [--shard i/n]        Eq.1 vs Eq.2 A/B
//! repro macsim   [--model M]                        flexible-MAC speedup table
//! repro bench step [--model M] [--scheme S] [--json F]  step-loop micro-benchmark
//! repro bench eval [--model M] [--scheme S] [--json F]  eval-pass micro-benchmark
//! repro trace summarize <file.jsonl>                analyze a --trace JSONL file
//! repro ckpt list|verify|prune --checkpoint-dir D   checkpoint maintenance
//! repro gen-data --out DIR [--n N]                  write synthetic IDX files
//! repro info                                        artifact/manifest summary
//! ```

use anyhow::{bail, Context, Result};

use qedps::cli::{Args, Spec};
use qedps::config::ExperimentConfig;
use qedps::coordinator::{self, figures, ShardOpts};
use qedps::runtime::Runtime;

const SPEC: Spec = Spec {
    name: "repro",
    about: "dynamic precision scaling training (Stuart & Taras 2018 reproduction)",
    flags: &[
        ("model", "mlp|lenet", "network (default lenet)"),
        ("scheme", "NAME", "policy: qedps|na|courbariaux|fixed|fixed13|gupta88|float|schedule"),
        ("iters", "N", "training iterations"),
        ("config", "FILE", "TOML config file"),
        ("set", "k=v", "config override (repeatable)"),
        ("fig", "3|4", "which figure (for `figures`)"),
        ("schemes", "a,b,c", "comma list (for `compare`)"),
        ("out", "DIR", "output dir (for `gen-data`)"),
        ("n", "N", "sample count (for `gen-data`)"),
        ("agg", "mean|max|last", "stat aggregation across sites"),
        ("checkpoint-dir", "DIR", "save checkpoints here"),
        ("keep", "N", "checkpoints to keep (GC / `ckpt prune`); 0 = keep all"),
        ("fault", "SPEC", "inject a fault: nan@N|inf@N|bitflip@N[:weight|grad]|read-fail[:N] (repeatable)"),
        ("fault-seed", "N", "seed for fault-site selection"),
        ("jobs", "N", "worker threads for multi-run sweeps (compare / fig 4 / rounding-ab)"),
        ("shard", "i/n", "run only the i-th of n sweep shards (1-based)"),
        ("trace", "FILE", "stream telemetry span/counter events to this JSONL file"),
        ("json", "FILE", "write machine-readable results here (for `bench step` / `bench eval`)"),
    ],
    switches: &[
        ("help", "show usage"),
        ("quiet", "warnings only"),
        ("resume", "resume from the newest complete checkpoint"),
        ("no-watchdog", "disable the divergence watchdog"),
        ("no-device-params", "keep params host-side (literal upload every step)"),
        ("no-eval-set", "rebuild eval batches every pass (disable the cached eval set)"),
    ],
};

fn build_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(m) = args.flag("model") {
        cfg.model = m.into();
    }
    if let Some(s) = args.flag("scheme") {
        cfg.scheme = s.into();
    }
    if let Some(i) = args.flag_parse::<u64>("iters")? {
        cfg.iters = i;
    }
    if let Some(a) = args.flag("agg") {
        cfg.agg = qedps::policy::AggMode::from_str(a)
            .ok_or_else(|| anyhow::anyhow!("--agg must be mean|max|last"))?;
    }
    if let Some(d) = args.flag("checkpoint-dir") {
        cfg.checkpoint_dir = Some(d.into());
    }
    if let Some(k) = args.flag_parse::<u64>("keep")? {
        cfg.keep_checkpoints = k;
    }
    for spec in args.flag_all("fault") {
        // fail fast on typos instead of mid-run
        qedps::resilience::parse_spec(spec)?;
        cfg.faults.push(spec.clone());
    }
    if let Some(s) = args.flag_parse::<u64>("fault-seed")? {
        cfg.fault_seed = s;
    }
    if args.switch("resume") {
        cfg.resume = true;
    }
    if args.switch("no-watchdog") {
        cfg.watchdog = false;
    }
    if args.switch("no-device-params") {
        cfg.device_params = false;
    }
    if args.switch("no-eval-set") {
        cfg.eval_set = false;
    }
    if let Some(t) = args.flag("trace") {
        cfg.trace_path = Some(t.into());
    }
    for kv in args.flag_all("set") {
        cfg.apply_set(kv)?;
    }
    Ok(cfg)
}

/// `repro bench step`: the step-loop micro-benchmark behind the pre-pinned
/// literal refactor and device-resident parameter state.  Reports step
/// latency, asserts the hot loop performs zero per-iteration literal
/// constructions and (when parameters stay device-resident) zero host↔device
/// state transfers, and prices what the pre-refactor
/// build-a-literal-per-input path would cost on top.
fn bench_step(cfg: &ExperimentConfig, iters: u64, json_out: Option<&str>) -> Result<()> {
    use qedps::bench::{bench_with, black_box, BenchOpts};
    use qedps::data::Batcher;
    use qedps::runtime::{host_transfers, literal_builds, literal_f32, literal_i32};
    use qedps::trainer::Trainer;

    let mut rt = Runtime::create()?;
    let ds = qedps::data::synth::generate(512, 5);
    let mut trainer = Trainer::new(&mut rt, cfg.clone())?;
    let mut batcher = Batcher::new(&ds, trainer.train_batch_size(), cfg.seed);

    println!(
        "== bench step: {}/{} ({iters} timed iters) ==",
        cfg.model, cfg.scheme
    );
    let opts = BenchOpts { warmup_iters: 3, min_iters: iters, min_time_s: 0.0 };
    let mut iter = 0u64;
    let telemetry_base = qedps::telemetry::snapshot();
    let before = literal_builds();
    let xfers_before = host_transfers();
    let step_r = bench_with(
        &format!("step/{}/{} (pinned inputs)", cfg.model, cfg.scheme),
        &opts,
        || {
            trainer.fill_batch(&mut batcher);
            black_box(trainer.step(iter).unwrap().loss);
            iter += 1;
        },
    );
    let builds = literal_builds() - before;
    let xfers = host_transfers() - xfers_before;
    println!("literal builds across {iter} steps: {builds} (target: 0)");
    if trainer.device_resident() {
        println!("host<->device state transfers across {iter} steps: {xfers} (target: 0)");
    } else {
        println!(
            "host<->device state transfers across {iter} steps: {xfers} \
             (host-literal fallback path; expected nonzero)"
        );
    }

    // what the pre-refactor path paid per iteration: five input literals
    // (x, y, lr, seed, prec) constructed from host buffers every step
    let meta = rt.manifest.model(&cfg.model)?;
    let mut x_shape = vec![rt.manifest.train_batch];
    x_shape.extend(meta.input_shape.iter().copied());
    let x_buf = vec![0.1f32; x_shape.iter().product()];
    let y_buf = vec![1i32; rt.manifest.train_batch];
    let prec_vec = [2.0f32, 14.0, 4.0, 12.0, 2.0, 20.0];
    bench_with(
        &format!("unpinned input build/{} (per-step cost removed)", cfg.model),
        &opts,
        || {
            black_box(literal_f32(&x_buf, &x_shape).unwrap());
            black_box(literal_i32(&y_buf, &[y_buf.len()]).unwrap());
            black_box(literal_f32(&[0.01], &[]).unwrap());
            black_box(literal_f32(&[1.0], &[]).unwrap());
            black_box(literal_f32(&prec_vec, &[6]).unwrap());
        },
    );
    anyhow::ensure!(
        builds == 0,
        "step loop constructed {builds} literals over {iter} iterations"
    );
    if trainer.device_resident() {
        anyhow::ensure!(
            xfers == 0,
            "device-resident step loop performed {xfers} host<->device state \
             transfers over {iter} iterations"
        );
        println!("ok: step hot path is literal-allocation-free and transfer-free");
    } else {
        println!(
            "ok: step hot path is literal-allocation-free \
             (device residency unavailable on this platform)"
        );
    }

    // Telemetry overhead budget: the instrumented step path holds ~6 spans
    // (engine.step/refill/quantize/exec/readback plus one of slack); with no
    // trace sink attached their combined cost must stay within 2% of the
    // measured step time.
    let span_opts = BenchOpts { warmup_iters: 100, min_iters: 10_000, min_time_s: 0.0 };
    let span_r = bench_with("telemetry span create+drop (no sink)", &span_opts, || {
        let _s = qedps::telemetry::span!("bench.span_probe");
        black_box(&_s);
    });
    let span_overhead_ns = span_r.mean_ns * 6.0;
    let budget_ns = step_r.mean_ns * 0.02;
    println!(
        "telemetry overhead: ~6 spans/step = {span_overhead_ns:.0} ns \
         vs 2% budget {budget_ns:.0} ns"
    );
    anyhow::ensure!(
        span_overhead_ns <= budget_ns,
        "telemetry span overhead {span_overhead_ns:.0} ns/step exceeds \
         2% of step time ({budget_ns:.0} ns)"
    );

    if let Some(path) = json_out {
        use qedps::util::json::Json;
        let delta = qedps::telemetry::snapshot().diff(&telemetry_base);
        let j = Json::obj(vec![
            ("bench", Json::Str("step".into())),
            ("model", Json::Str(cfg.model.clone())),
            ("scheme", Json::Str(cfg.scheme.clone())),
            ("iters", Json::Num(step_r.iters as f64)),
            ("mean_step_ns", Json::Num(step_r.mean_ns)),
            ("stddev_step_ns", Json::Num(step_r.stddev_ns)),
            ("min_step_ns", Json::Num(step_r.min_ns)),
            ("literal_builds", Json::Num(builds as f64)),
            ("host_transfers", Json::Num(xfers as f64)),
            ("span_overhead_ns", Json::Num(span_overhead_ns)),
            ("telemetry", delta.to_json()),
        ]);
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, j.to_string_pretty())?;
        println!("wrote bench json -> {path}");
    }
    Ok(())
}

/// `repro bench eval`: the eval-pass micro-benchmark behind the cached
/// eval set.  After warmup, every timed pass must perform zero literal
/// constructions and zero host→device input uploads (the set is batched
/// and resident); with device-resident parameters the pass must also be
/// free of state uploads and counted host transfers.  The legacy per-pass
/// refill path is timed alongside as the cost the cache removes, and both
/// paths must agree bit-for-bit.
fn bench_eval(cfg: &ExperimentConfig, passes: u64, json_out: Option<&str>) -> Result<()> {
    use qedps::bench::{bench_with, black_box, BenchOpts, EvalBenchReport};
    use qedps::runtime::{host_transfers, literal_builds};
    use qedps::trainer::Trainer;

    let mut rt = Runtime::create()?;
    // deliberately not a multiple of any eval batch, so the tail-mask
    // (`valid`) path is always part of what gets timed and asserted
    let test = qedps::data::synth::generate(333, 6);
    let mut trainer = Trainer::new(&mut rt, cfg.clone())?;
    let eval_batch = trainer.eval_batch_size();
    let batches = test.n.div_ceil(eval_batch);

    println!(
        "== bench eval: {}/{} ({} examples, batch {eval_batch}, {passes} timed passes) ==",
        cfg.model, cfg.scheme, test.n
    );

    // Warm up outside the timed window: the first pass builds the eval set
    // and uploads each batch's inputs once; the second demonstrates the
    // steady state the assertions below pin.
    black_box(trainer.evaluate(&test)?);
    black_box(trainer.evaluate(&test)?);

    let telemetry_base = qedps::telemetry::snapshot();
    let builds_before = literal_builds();
    let xfers_before = host_transfers();
    let opts = BenchOpts { warmup_iters: 0, min_iters: passes, min_time_s: 0.0 };
    let pass_r = bench_with(
        &format!("eval/{}/{} (cached eval set)", cfg.model, cfg.scheme),
        &opts,
        || {
            black_box(trainer.evaluate(&test).unwrap());
        },
    );
    let builds = literal_builds() - builds_before;
    let xfers = host_transfers() - xfers_before;
    let delta = qedps::telemetry::snapshot().diff(&telemetry_base);
    let h2d_state = delta.counter("device.h2d_state");
    let h2d_input = delta.counter("device.h2d_input");

    println!("literal builds across {} passes: {builds} (target: 0)", pass_r.iters);
    println!(
        "input uploads (device.h2d_input) across {} passes: {h2d_input} (target: 0)",
        pass_r.iters
    );
    if trainer.device_resident() {
        println!(
            "state uploads (device.h2d_state) across {} passes: {h2d_state} (target: 0)",
            pass_r.iters
        );
    } else {
        println!(
            "state uploads (device.h2d_state) across {} passes: {h2d_state} \
             (host mode re-uploads parameters once per pass)",
            pass_r.iters
        );
    }

    // The cost the cache removes: the legacy path re-batches the test set
    // and re-uploads the inputs on every pass.
    let mut legacy_cfg = cfg.clone();
    legacy_cfg.eval_set = false;
    let mut legacy = Trainer::new(&mut rt, legacy_cfg)?;
    black_box(legacy.evaluate(&test)?);
    bench_with(
        &format!("eval/{}/{} (per-pass refill, cost removed)", cfg.model, cfg.scheme),
        &opts,
        || {
            black_box(legacy.evaluate(&test).unwrap());
        },
    );
    let (cl, ca) = trainer.evaluate(&test)?;
    let (ll, la) = legacy.evaluate(&test)?;
    anyhow::ensure!(
        cl.to_bits() == ll.to_bits() && ca.to_bits() == la.to_bits(),
        "cached eval set and per-pass refill disagree: ({cl}, {ca}) vs ({ll}, {la})"
    );

    anyhow::ensure!(
        builds == 0,
        "steady-state eval constructed {builds} literals over {} passes",
        pass_r.iters
    );
    anyhow::ensure!(
        h2d_input == 0,
        "steady-state eval uploaded {h2d_input} input buffers over {} passes",
        pass_r.iters
    );
    if trainer.device_resident() {
        anyhow::ensure!(
            h2d_state == 0 && xfers == 0,
            "device-resident eval performed {h2d_state} state uploads and \
             {xfers} counted host transfers over {} passes",
            pass_r.iters
        );
        println!("ok: steady-state eval pass is prep-free, upload-free, and transfer-free");
    } else {
        println!(
            "ok: steady-state eval pass is literal-free and input-upload-free \
             (host-mode per-pass state re-upload expected)"
        );
    }

    if let Some(path) = json_out {
        let report = EvalBenchReport {
            model: cfg.model.clone(),
            scheme: cfg.scheme.clone(),
            passes: pass_r.iters,
            batches_per_pass: batches,
            examples: test.n,
            mean_pass_ns: pass_r.mean_ns,
            stddev_pass_ns: pass_r.stddev_ns,
            min_pass_ns: pass_r.min_ns,
            literal_builds: builds,
            h2d_state,
            h2d_input,
            host_transfers: xfers,
            device_resident: trainer.device_resident(),
            telemetry: delta.to_json(),
        };
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, report.to_json().to_string_pretty())?;
        println!("wrote bench json -> {path}");
    }
    Ok(())
}

fn shard_opts(args: &Args) -> Result<ShardOpts> {
    Ok(ShardOpts {
        jobs: args.flag_parse::<usize>("jobs")?.unwrap_or(1).max(1),
        shard: args
            .flag("shard")
            .map(coordinator::Shard::parse)
            .transpose()?,
    })
}

fn main() -> Result<()> {
    qedps::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, rest)) if !s.starts_with("--") => (s.clone(), rest.to_vec()),
        _ => ("help".to_string(), argv),
    };
    let args = Args::parse(&SPEC, &rest)?;
    if args.switch("quiet") {
        qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    }
    if args.switch("help") || sub == "help" {
        print!("{}", SPEC.usage());
        println!(
            "\nsubcommands: train figures compare rounding-ab macsim bench trace ckpt gen-data info"
        );
        return Ok(());
    }

    match sub.as_str() {
        "train" => {
            let cfg = build_config(&args)?;
            let mut rt = Runtime::create()?;
            let tag = format!("train_{}_{}", cfg.model, cfg.scheme);
            let hist = coordinator::run_and_record(&mut rt, &cfg, &tag)?;
            let s = hist.summary();
            println!("\n=== {tag} ===");
            println!("final test acc : {:.4}", s.final_test_acc);
            println!("best test acc  : {:.4}", s.best_test_acc);
            println!("mean bits (w/a/g): {:.1} / {:.1} / {:.1}",
                     s.mean_weight_bits, s.mean_act_bits, s.mean_grad_bits);
            println!("mean step time : {:.1} ms", s.mean_step_ms);
            println!("status         : {}", s.status.as_str());
            if s.recoveries > 0 {
                println!("recoveries     : {} (see summary JSON for the event trail)",
                         s.recoveries);
            }
            println!("records under  : {}", cfg.out_dir);
        }
        "figures" => {
            let cfg = build_config(&args)?;
            let opts = shard_opts(&args)?;
            let fig4_dispatch = |cfg: &ExperimentConfig| -> Result<()> {
                // fan the three scheme runs out when asked; jobs=1 without a
                // shard takes the same code path and emits identical output
                if opts.jobs > 1 || opts.shard.is_some() {
                    figures::fig4_sharded(cfg, &opts)?;
                } else {
                    let mut rt = Runtime::create()?;
                    figures::fig4(&mut rt, cfg)?;
                }
                Ok(())
            };
            match args.flag("fig") {
                Some("3") => {
                    let mut rt = Runtime::create()?;
                    figures::fig3(&mut rt, &cfg)?;
                }
                Some("4") => fig4_dispatch(&cfg)?,
                _ => {
                    let mut rt = Runtime::create()?;
                    figures::fig3(&mut rt, &cfg)?;
                    drop(rt);
                    fig4_dispatch(&cfg)?;
                }
            }
        }
        "compare" if args.pos(0) == Some("merge") => {
            // `repro compare merge <files...>` — join per-shard slices back
            // into the byte-identical serial compare.json.
            let cfg = build_config(&args)?;
            let files = &args.positional[1..];
            anyhow::ensure!(
                !files.is_empty(),
                "compare merge needs at least one compare.shard-i-of-n.json file"
            );
            let mut slices = Vec::with_capacity(files.len());
            for f in files {
                let text = std::fs::read_to_string(f)
                    .with_context(|| format!("reading shard slice {f}"))?;
                slices.push(
                    coordinator::parse_shard_slice(&text)
                        .with_context(|| format!("parsing shard slice {f}"))?,
                );
            }
            let rows = coordinator::merge_shard_slices(&slices)?;
            coordinator::print_compare_table(&rows);
            let out = std::path::Path::new(&cfg.out_dir).join("compare.json");
            std::fs::create_dir_all(&cfg.out_dir)?;
            std::fs::write(&out, coordinator::compare_rows_json(&rows).to_string_pretty())?;
            println!("merged {} shard slices -> {}", slices.len(), out.display());
        }
        "compare" => {
            let cfg = build_config(&args)?;
            let opts = shard_opts(&args)?;
            let schemes_owned: Vec<String> = args
                .flag("schemes")
                .unwrap_or("qedps,na,courbariaux,gupta88,fixed13,float")
                .split(',')
                .map(|s| s.trim().to_string())
                .collect();
            let schemes: Vec<&str> = schemes_owned.iter().map(|s| s.as_str()).collect();
            // serial and threaded runs share one dispatch path, so
            // `--jobs 2` emits byte-identical tables to `--jobs 1`
            let rows = coordinator::compare_schemes_sharded(&cfg, &schemes, &opts)?;
            let done: Vec<coordinator::CompareRow> = rows.iter().flatten().cloned().collect();
            coordinator::print_compare_table(&done);
            std::fs::create_dir_all(&cfg.out_dir)?;
            let (out_name, json) = match &opts.shard {
                // each subprocess shard writes its indexed slice;
                // `repro compare merge` joins them offline
                Some(s) => (
                    format!("compare.shard-{}-of-{}.json", s.index + 1, s.of),
                    coordinator::compare_shard_json(&rows, s),
                ),
                None => ("compare.json".to_string(), coordinator::compare_rows_json(&done)),
            };
            let out = std::path::Path::new(&cfg.out_dir).join(out_name);
            std::fs::write(&out, json.to_string_pretty())?;
            println!("wrote {}", out.display());
        }
        "rounding-ab" => {
            let cfg = build_config(&args)?;
            let opts = shard_opts(&args)?;
            // same dispatch contract as fig 4: the sharded path with jobs=1
            // and no shard filter emits byte-identical output to the serial
            // path, so either route satisfies the equivalence tests
            if opts.jobs > 1 || opts.shard.is_some() {
                figures::rounding_ab_sharded(&cfg, &opts)?;
            } else {
                let mut rt = Runtime::create()?;
                figures::rounding_ab(&mut rt, &cfg)?;
            }
        }
        "macsim" => {
            let cfg = build_config(&args)?;
            let rt = Runtime::create()?;
            figures::macsim_report(&rt, &cfg.model)?;
        }
        "bench" => match args.pos(0).unwrap_or("step") {
            "step" => {
                let cfg = build_config(&args)?;
                let iters = args.flag_parse::<u64>("iters")?.unwrap_or(50).max(1);
                bench_step(&cfg, iters, args.flag("json"))?;
            }
            "eval" => {
                let cfg = build_config(&args)?;
                let passes = args.flag_parse::<u64>("iters")?.unwrap_or(10).max(1);
                bench_eval(&cfg, passes, args.flag("json"))?;
            }
            other => {
                bail!("unknown bench target '{other}' — try `repro bench step` or `repro bench eval`")
            }
        },
        "trace" => match args.pos(0) {
            Some("summarize") => {
                let file = args
                    .pos(1)
                    .context("trace summarize needs a trace file (JSONL from --trace)")?;
                let summary = qedps::telemetry::trace::summarize(file)?;
                print!("{}", summary.render());
            }
            _ => bail!("unknown trace action — try `repro trace summarize <file.jsonl>`"),
        },
        "ckpt" => {
            use qedps::trainer::checkpoint;
            let cfg = build_config(&args)?;
            let dir = cfg
                .checkpoint_dir
                .clone()
                .context("ckpt needs --checkpoint-dir")?;
            match args.pos(0).unwrap_or("list") {
                "list" => {
                    let iters = checkpoint::list_candidates(&dir);
                    if iters.is_empty() {
                        println!("no checkpoints under {dir}");
                    }
                    for iter in iters {
                        let step_dir =
                            std::path::Path::new(&dir).join(format!("state-{iter}"));
                        match checkpoint::validate(&step_dir) {
                            Ok(m) => println!(
                                "state-{iter:<8} ok       model={} scheme={} prec w={} a={} g={}",
                                m.model, m.scheme, m.prec.weights, m.prec.acts, m.prec.grads
                            ),
                            Err(e) => println!("state-{iter:<8} INVALID  {e:#}"),
                        }
                    }
                }
                "verify" => {
                    let iters = checkpoint::list_candidates(&dir);
                    let mut bad = 0usize;
                    for iter in &iters {
                        let step_dir =
                            std::path::Path::new(&dir).join(format!("state-{iter}"));
                        if let Err(e) = checkpoint::validate(&step_dir) {
                            println!("state-{iter}: {e:#}");
                            bad += 1;
                        }
                    }
                    println!("{} checkpoints, {} invalid", iters.len(), bad);
                    anyhow::ensure!(bad == 0, "{bad} checkpoints failed validation");
                }
                "prune" => {
                    let n = checkpoint::gc(&dir, cfg.keep_checkpoints)?;
                    println!(
                        "pruned {n} checkpoints (keeping newest {})",
                        cfg.keep_checkpoints
                    );
                }
                other => bail!("unknown ckpt action '{other}' — try list|verify|prune"),
            }
        }
        "gen-data" => {
            let out = args.flag("out").unwrap_or("data/synth");
            let n = args.flag_parse::<usize>("n")?.unwrap_or(10_000);
            let dir = std::path::Path::new(out);
            std::fs::create_dir_all(dir)?;
            let train = qedps::data::synth::generate(n, 2018);
            let test = qedps::data::synth::generate(n / 5, 2019);
            qedps::data::mnist::write_idx_images(&dir.join("train-images-idx3-ubyte"), &train)?;
            qedps::data::mnist::write_idx_labels(&dir.join("train-labels-idx1-ubyte"), &train)?;
            qedps::data::mnist::write_idx_images(&dir.join("t10k-images-idx3-ubyte"), &test)?;
            qedps::data::mnist::write_idx_labels(&dir.join("t10k-labels-idx1-ubyte"), &test)?;
            println!("wrote {} train / {} test IDX files to {}", train.n, test.n, out);
        }
        "info" => {
            let rt = Runtime::create()?;
            println!("artifacts: {}", rt.dir.display());
            println!("platform : {}", rt.client.platform_name());
            println!("batches  : train={} eval={}", rt.manifest.train_batch,
                     rt.manifest.eval_batch);
            println!("\nmodels:");
            for (name, m) in &rt.manifest.models {
                println!("  {name}: {} params in {} tensors, input {:?}",
                         m.param_count(), m.params.len(), m.input_shape);
            }
            println!("\nmodules:");
            for (name, m) in &rt.manifest.modules {
                println!("  {name:<22} kind={:<9} in={:<2} out={:<2} sites={}",
                         m.kind, m.inputs.len(), m.outputs.len(), m.sites.len());
            }
        }
        other => bail!("unknown subcommand '{other}' — try `repro help`"),
    }
    Ok(())
}
