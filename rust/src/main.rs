//! `repro` — the qedps launcher.
//!
//! ```text
//! repro train    [--model M] [--scheme S] [--iters N] [--config F] [--set k=v]...
//! repro figures  --fig 3|4   [same flags]           regenerate paper figures
//! repro compare  [--schemes a,b,c]                  Table-1 head-to-head
//! repro rounding-ab                                 Eq.1 vs Eq.2 A/B
//! repro macsim   [--model M]                        flexible-MAC speedup table
//! repro gen-data --out DIR [--n N]                  write synthetic IDX files
//! repro info                                        artifact/manifest summary
//! ```

use anyhow::{bail, Result};

use qedps::cli::{Args, Spec};
use qedps::config::ExperimentConfig;
use qedps::coordinator::{self, figures};
use qedps::runtime::Runtime;

const SPEC: Spec = Spec {
    name: "repro",
    about: "dynamic precision scaling training (Stuart & Taras 2018 reproduction)",
    flags: &[
        ("model", "mlp|lenet", "network (default lenet)"),
        ("scheme", "NAME", "policy: qedps|na|courbariaux|fixed|fixed13|gupta88|float|schedule"),
        ("iters", "N", "training iterations"),
        ("config", "FILE", "TOML config file"),
        ("set", "k=v", "config override (repeatable)"),
        ("fig", "3|4", "which figure (for `figures`)"),
        ("schemes", "a,b,c", "comma list (for `compare`)"),
        ("out", "DIR", "output dir (for `gen-data`)"),
        ("n", "N", "sample count (for `gen-data`)"),
        ("agg", "mean|max|last", "stat aggregation across sites"),
        ("checkpoint-dir", "DIR", "save checkpoints here"),
        ("fault", "SPEC", "inject a fault: nan@N|inf@N|bitflip@N[:weight|grad]|read-fail[:N] (repeatable)"),
        ("fault-seed", "N", "seed for fault-site selection"),
    ],
    switches: &[
        ("help", "show usage"),
        ("quiet", "warnings only"),
        ("resume", "resume from the newest complete checkpoint"),
        ("no-watchdog", "disable the divergence watchdog"),
    ],
};

fn build_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.flag("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(m) = args.flag("model") {
        cfg.model = m.into();
    }
    if let Some(s) = args.flag("scheme") {
        cfg.scheme = s.into();
    }
    if let Some(i) = args.flag_parse::<u64>("iters")? {
        cfg.iters = i;
    }
    if let Some(a) = args.flag("agg") {
        cfg.agg = qedps::policy::AggMode::from_str(a)
            .ok_or_else(|| anyhow::anyhow!("--agg must be mean|max|last"))?;
    }
    if let Some(d) = args.flag("checkpoint-dir") {
        cfg.checkpoint_dir = Some(d.into());
    }
    for spec in args.flag_all("fault") {
        // fail fast on typos instead of mid-run
        qedps::resilience::parse_spec(spec)?;
        cfg.faults.push(spec.clone());
    }
    if let Some(s) = args.flag_parse::<u64>("fault-seed")? {
        cfg.fault_seed = s;
    }
    if args.switch("resume") {
        cfg.resume = true;
    }
    if args.switch("no-watchdog") {
        cfg.watchdog = false;
    }
    for kv in args.flag_all("set") {
        cfg.apply_set(kv)?;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    qedps::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, rest)) if !s.starts_with("--") => (s.clone(), rest.to_vec()),
        _ => ("help".to_string(), argv),
    };
    let args = Args::parse(&SPEC, &rest)?;
    if args.switch("quiet") {
        qedps::util::logging::set_level(qedps::util::logging::Level::Warn);
    }
    if args.switch("help") || sub == "help" {
        print!("{}", SPEC.usage());
        println!("\nsubcommands: train figures compare rounding-ab macsim gen-data info");
        return Ok(());
    }

    match sub.as_str() {
        "train" => {
            let cfg = build_config(&args)?;
            let mut rt = Runtime::create()?;
            let tag = format!("train_{}_{}", cfg.model, cfg.scheme);
            let hist = coordinator::run_and_record(&mut rt, &cfg, &tag)?;
            let s = hist.summary();
            println!("\n=== {tag} ===");
            println!("final test acc : {:.4}", s.final_test_acc);
            println!("best test acc  : {:.4}", s.best_test_acc);
            println!("mean bits (w/a/g): {:.1} / {:.1} / {:.1}",
                     s.mean_weight_bits, s.mean_act_bits, s.mean_grad_bits);
            println!("mean step time : {:.1} ms", s.mean_step_ms);
            println!("status         : {}", s.status.as_str());
            if s.recoveries > 0 {
                println!("recoveries     : {} (see summary JSON for the event trail)",
                         s.recoveries);
            }
            println!("records under  : {}", cfg.out_dir);
        }
        "figures" => {
            let cfg = build_config(&args)?;
            let mut rt = Runtime::create()?;
            match args.flag("fig") {
                Some("3") => {
                    figures::fig3(&mut rt, &cfg)?;
                }
                Some("4") => {
                    figures::fig4(&mut rt, &cfg)?;
                }
                _ => {
                    figures::fig3(&mut rt, &cfg)?;
                    figures::fig4(&mut rt, &cfg)?;
                }
            }
        }
        "compare" => {
            let cfg = build_config(&args)?;
            let schemes_owned: Vec<String> = args
                .flag("schemes")
                .unwrap_or("qedps,na,courbariaux,gupta88,fixed13,float")
                .split(',')
                .map(|s| s.trim().to_string())
                .collect();
            let schemes: Vec<&str> = schemes_owned.iter().map(|s| s.as_str()).collect();
            let mut rt = Runtime::create()?;
            let rows = coordinator::compare_schemes(&mut rt, &cfg, &schemes)?;
            coordinator::print_compare_table(&rows);
            let out = std::path::Path::new(&cfg.out_dir).join("compare.json");
            std::fs::create_dir_all(&cfg.out_dir)?;
            std::fs::write(&out, coordinator::compare_rows_json(&rows).to_string_pretty())?;
            println!("wrote {}", out.display());
        }
        "rounding-ab" => {
            let cfg = build_config(&args)?;
            let mut rt = Runtime::create()?;
            figures::rounding_ab(&mut rt, &cfg)?;
        }
        "macsim" => {
            let cfg = build_config(&args)?;
            let rt = Runtime::create()?;
            figures::macsim_report(&rt, &cfg.model)?;
        }
        "gen-data" => {
            let out = args.flag("out").unwrap_or("data/synth");
            let n = args.flag_parse::<usize>("n")?.unwrap_or(10_000);
            let dir = std::path::Path::new(out);
            std::fs::create_dir_all(dir)?;
            let train = qedps::data::synth::generate(n, 2018);
            let test = qedps::data::synth::generate(n / 5, 2019);
            qedps::data::mnist::write_idx_images(&dir.join("train-images-idx3-ubyte"), &train)?;
            qedps::data::mnist::write_idx_labels(&dir.join("train-labels-idx1-ubyte"), &train)?;
            qedps::data::mnist::write_idx_images(&dir.join("t10k-images-idx3-ubyte"), &test)?;
            qedps::data::mnist::write_idx_labels(&dir.join("t10k-labels-idx1-ubyte"), &test)?;
            println!("wrote {} train / {} test IDX files to {}", train.n, test.n, out);
        }
        "info" => {
            let rt = Runtime::create()?;
            println!("artifacts: {}", rt.dir.display());
            println!("platform : {}", rt.client.platform_name());
            println!("batches  : train={} eval={}", rt.manifest.train_batch,
                     rt.manifest.eval_batch);
            println!("\nmodels:");
            for (name, m) in &rt.manifest.models {
                println!("  {name}: {} params in {} tensors, input {:?}",
                         m.param_count(), m.params.len(), m.input_shape);
            }
            println!("\nmodules:");
            for (name, m) in &rt.manifest.modules {
                println!("  {name:<22} kind={:<9} in={:<2} out={:<2} sites={}",
                         m.kind, m.inputs.len(), m.outputs.len(), m.sites.len());
            }
        }
        other => bail!("unknown subcommand '{other}' — try `repro help`"),
    }
    Ok(())
}
