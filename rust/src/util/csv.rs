//! Tiny CSV writer (and reader, for tests) used by the metric exporters.
//! Values are written with enough precision to round-trip f64.

use std::io::Write;
use std::path::Path;

pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(Self { file, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> anyhow::Result<()> {
        anyhow::ensure!(
            values.len() == self.cols,
            "csv row has {} values, header has {}",
            values.len(),
            self.cols
        );
        let mut line = String::new();
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if *v == v.trunc() && v.abs() < 1e15 {
                line.push_str(&format!("{}", *v as i64));
            } else {
                line.push_str(&format!("{:.9}", v));
            }
        }
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Parse a simple (unquoted) CSV back: header + rows of f64.
pub fn read_csv<P: AsRef<Path>>(path: P) -> anyhow::Result<(Vec<String>, Vec<Vec<f64>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty csv"))?
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let mut rows = Vec::new();
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        rows.push(
            line.split(',')
                .map(|s| s.trim().parse::<f64>())
                .collect::<Result<Vec<_>, _>>()?,
        );
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("qedps_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["iter", "loss", "bits"]).unwrap();
        w.row(&[0.0, 2.302585, 16.0]).unwrap();
        w.row(&[1.0, 1.5, 14.0]).unwrap();
        w.flush().unwrap();
        drop(w);
        let (header, rows) = read_csv(&path).unwrap();
        assert_eq!(header, vec!["iter", "loss", "bits"]);
        assert_eq!(rows.len(), 2);
        assert!((rows[0][1] - 2.302585).abs() < 1e-6);
        assert_eq!(rows[1][2], 14.0);
    }

    #[test]
    fn wrong_arity_rejected() {
        let dir = std::env::temp_dir().join("qedps_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&[1.0]).is_err());
    }
}
