//! Minimal-but-complete JSON parser and writer (serde is unavailable
//! offline; the manifest, configs, checkpoints and metric exports all go
//! through this module).
//!
//! Supports the full JSON grammar (RFC 8259): objects, arrays, strings with
//! escapes (incl. `\uXXXX` + surrogate pairs), numbers, booleans, null.
//! Numbers are stored as `f64` (the manifest only carries shapes/counts,
//! well within exact-integer f64 range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------ accessors
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// `obj["key"]`-style access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Array element access; `Json::Null` when out of range.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    // ----------------------------------------------------------- builders
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_str(v: &[&str]) -> Json {
        Json::Arr(v.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ------------------------------------------------------------- parsing
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------- writing
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (k, item) in items.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (k, (key, val)) in map.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_str(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").at(1).as_f64(), Some(2.0));
        assert!(v.get("a").at(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn parse_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m": {"x": [0, 1.5, -3], "s": "a\"b", "t": true}, "n": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn missing_key_is_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").is_null());
        assert!(v.get("nope").get("deeper").is_null());
    }

    #[test]
    fn integers_stay_integral() {
        let v = Json::Num(1234567.0);
        assert_eq!(v.to_string(), "1234567");
    }
}
