//! In-repo substrates: JSON, CSV, RNG, logging, timing.
//!
//! The offline crate cache contains only the `xla` crate's closure, so the
//! usual serde/rand/env_logger roles are implemented here (DESIGN.md §2).

pub mod csv;
pub mod json;
pub mod logging;
pub mod rng;

/// Wall-clock stopwatch returning seconds as f64.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Simple mean/min/max/stddev accumulator for timing and metrics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: u64,
    pub sum: f64,
    pub sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64 - m * m).max(0.0)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0] {
            s.add(v);
        }
        assert_eq!(s.n, 3);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.stddev() - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }
}
