//! Leveled stderr logger (env_logger is unavailable offline).
//!
//! Level comes from `QEDPS_LOG` (`error|warn|info|debug|trace`), default
//! `info`.  Messages carry a wall-clock offset from process start so step
//! timing is readable in experiment logs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Once, OnceLock};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("QEDPS_LOG") {
        match parse_level(&v) {
            Some(l) => set_level(l),
            None => {
                // an unrecognized value still runs at the default level, but
                // never silently: say once what was rejected and what works
                static WARNED: Once = Once::new();
                WARNED.call_once(|| {
                    log(
                        Level::Warn,
                        format_args!(
                            "QEDPS_LOG={v:?} is not a level \
                             (accepted: error|warn|info|debug|trace); using info"
                        ),
                    );
                });
                set_level(Level::Info);
            }
        }
    }
}

/// Parse a `QEDPS_LOG` value; `None` for anything outside the accepted set.
pub fn parse_level(v: &str) -> Option<Level> {
    match v.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

/// Product output (tables, figures, reports) — plain stdout, no log
/// prefix, never level-gated.  All stdout printing funnels through here so
/// `scripts/tier1.sh`'s print-discipline lint can ban bare `println!` in
/// library code.
pub fn out(args: std::fmt::Arguments) {
    use std::io::Write;
    let _ = writeln!(std::io::stdout(), "{args}");
}

/// `crate::out!(...)` — [`out`] with `println!` syntax (empty call prints a
/// blank line).
#[macro_export]
macro_rules! out {
    () => {
        $crate::util::logging::out(format_args!(""))
    };
    ($($arg:tt)*) => {
        $crate::util::logging::out(format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parse_level_accepts_the_documented_set_only() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info), "info is explicit");
        assert_eq!(parse_level("Debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        for bad in ["", "verbose", "infoo", "2", "warning"] {
            assert_eq!(parse_level(bad), None, "{bad:?} must be rejected");
        }
    }
}
