//! In-repo PRNGs (the `rand` crate is unavailable offline).
//!
//! Two generators with different jobs:
//!
//! * [`Pcg32`] — fast general-purpose stream RNG for data generation,
//!   shuffling and property-test case generation (PCG-XSH-RR 64/32,
//!   O'Neill 2014).
//! * [`hash_u32`] / [`uniform01`] — the *counter-based* hash that is the
//!   specification of the L1 kernel's stochastic-rounding noise.  This must
//!   stay bit-identical to `python/compile/kernels/quantize.py`; the parity
//!   test `rust/tests/quantize_parity.rs` holds the two together.

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24-bit resolution.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire rejection-free-ish; fine for
    /// non-cryptographic use).
    pub fn below(&mut self, bound: u32) -> u32 {
        ((self.next_u32() as u64 * bound as u64) >> 32) as u32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            v.swap(i, j);
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel-noise hash (spec shared with the Pallas kernel)
// ---------------------------------------------------------------------------

pub const GOLDEN: u32 = 0x9E37_79B9;
pub const MIX1: u32 = 0x85EB_CA6B;
pub const MIX2: u32 = 0xC2B2_AE35;

/// murmur3-finalizer avalanche over `idx * GOLDEN + seed`; bit-identical to
/// `kernels/quantize.py::hash_u32`.
#[inline]
pub fn hash_u32(idx: u32, seed: u32) -> u32 {
    let mut x = idx.wrapping_mul(GOLDEN).wrapping_add(seed);
    x ^= x >> 16;
    x = x.wrapping_mul(MIX1);
    x ^= x >> 13;
    x = x.wrapping_mul(MIX2);
    x ^ (x >> 16)
}

/// U[0,1) with a 24-bit mantissa; bit-identical to
/// `kernels/quantize.py::uniform01`.
#[inline]
pub fn uniform01(idx: u32, seed: u32) -> f32 {
    (hash_u32(idx, seed) >> 8) as f32 * (1.0 / (1 << 24) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_range_and_centered() {
        let mut r = Pcg32::seeded(7);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(3);
        let mut seen0 = false;
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen0 |= v == 0;
        }
        assert!(seen0);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let (mut s, mut s2) = (0.0, 0.0);
        let n = 20_000;
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    /// Pinned vectors shared with python/tests/test_kernel.py — the hash is
    /// a cross-language spec.
    #[test]
    fn hash_reference_vectors() {
        let want: Vec<u32> = [0u32, 1, 2, 12345, 0xFFFF_FFFF]
            .iter()
            .map(|&i| hash_u32(i, 42))
            .collect();
        // recompute independently
        fn mix(i: u64, s: u64) -> u32 {
            let mut x = ((i * 0x9E3779B9 + s) & 0xFFFF_FFFF) as u32;
            x ^= x >> 16;
            x = ((x as u64 * 0x85EBCA6B) & 0xFFFF_FFFF) as u32;
            x ^= x >> 13;
            x = ((x as u64 * 0xC2B2AE35) & 0xFFFF_FFFF) as u32;
            x ^ (x >> 16)
        }
        let alt: Vec<u32> = [0u64, 1, 2, 12345, 0xFFFF_FFFF]
            .iter()
            .map(|&i| mix(i, 42))
            .collect();
        assert_eq!(want, alt);
    }

    #[test]
    fn uniform01_range() {
        for i in 0..10_000u32 {
            let u = uniform01(i, 7);
            assert!((0.0..1.0).contains(&u));
        }
    }
}
