//! Minimal TOML-subset parser (the `toml` crate is unavailable offline).
//!
//! Supported: `[section]` headers, `key = value` with string / integer /
//! float / bool / homogeneous scalar arrays, `#` comments, blank lines.
//! This covers the whole config surface of the repo; nested tables and
//! datetimes are intentionally out of scope and rejected loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// `section.key -> value`; keys before any section live under `""`.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

pub fn parse(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            if name.contains('[') || name.contains('.') {
                bail!("line {}: nested tables unsupported", lineno + 1);
            }
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected 'key = value'", lineno + 1);
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow::anyhow!("line {}: {}", lineno + 1, e))?;
        doc.get_mut(&section).unwrap().insert(key.to_string(), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("missing value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            bail!("unterminated string");
        };
        return Ok(TomlValue::Str(unescape(inner)?));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            bail!("unterminated array");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_array(inner)?
            .into_iter()
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Arr(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(v) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    bail!("cannot parse value '{s}'")
}

fn split_array(s: &str) -> Result<Vec<&str>> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    Ok(out)
}

fn unescape(s: &str) -> Result<String> {
    let mut out = String::new();
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match it.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => bail!("bad escape \\{other:?}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_typical_config() {
        let doc = parse(
            r#"
            # experiment
            scheme = "qedps"
            iters = 3000
            [policy]
            e_max = 1e-4
            init = [2, 14]   # IL, FL
            stochastic = true
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["scheme"].as_str(), Some("qedps"));
        assert_eq!(doc[""]["iters"].as_i64(), Some(3000));
        assert_eq!(doc["policy"]["e_max"].as_f64(), Some(1e-4));
        assert_eq!(doc["policy"]["stochastic"].as_bool(), Some(true));
        match &doc["policy"]["init"] {
            TomlValue::Arr(v) => assert_eq!(v.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn comments_inside_strings_kept() {
        let doc = parse(r##"path = "a#b" # real comment"##).unwrap();
        assert_eq!(doc[""]["path"].as_str(), Some("a#b"));
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("a = 3\nb = 3.5\nc = 1_000").unwrap();
        assert_eq!(doc[""]["a"], TomlValue::Int(3));
        assert_eq!(doc[""]["b"], TomlValue::Float(3.5));
        assert_eq!(doc[""]["c"], TomlValue::Int(1000));
        assert_eq!(doc[""]["a"].as_f64(), Some(3.0));
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(parse("[open").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("= 3").is_err());
        assert!(parse("x = [1, 2").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("[a.b]").is_err());
        assert!(parse("just a line").is_err());
    }

    #[test]
    fn escapes() {
        let doc = parse(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc[""]["s"].as_str(), Some("a\nb\t\"c\""));
    }
}
