//! Experiment configuration: schema + TOML-subset loader + CLI overrides.
//!
//! Resolution order: built-in defaults (the paper's hyperparameters) <
//! `--config file.toml` < individual CLI flags.  `configs/` in the repo
//! ships one file per paper experiment.

pub mod toml;

use anyhow::{bail, Context, Result};

use crate::fixedpoint::Format;
use crate::policy::{AggMode, PolicyOptions, PrecState};
use toml::{TomlDoc, TomlValue};

/// Everything one training run needs (the paper's §4 settings are the
/// defaults).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// `mlp` or `lenet`.
    pub model: String,
    /// Policy scheme name (see [`crate::policy::make_policy`]).
    pub scheme: String,
    pub iters: u64,
    /// Initial learning rate (paper: 0.01).
    pub lr0: f64,
    /// Inverse-decay gamma (paper: 1e-4).
    pub gamma: f64,
    /// Inverse-decay power (paper: 0.75).
    pub power: f64,
    /// E_max / R_max thresholds (paper: 0.01% = 1e-4).
    pub e_max: f64,
    pub r_max: f64,
    /// Initial precision per class.
    pub init_weights: Format,
    pub init_acts: Format,
    pub init_grads: Format,
    /// Stat aggregation across sites of a class.
    pub agg: AggMode,
    /// Dataset sizes (synthetic path) and seeds.
    pub train_n: usize,
    pub test_n: usize,
    pub seed: u64,
    /// Evaluate on the test set every N iterations (0 = only at the end).
    pub eval_every: u64,
    /// Log/record metrics every N iterations.
    pub log_every: u64,
    /// Force an artifact rounding mode regardless of the policy's default
    /// (`"stochastic"`/`"nearest"`) — used by the Eq.1-vs-Eq.2 A/B.
    pub force_rounding: Option<String>,
    /// Output directory for CSV/JSON records.
    pub out_dir: String,
    /// Optional checkpoint directory.
    pub checkpoint_dir: Option<String>,
    pub checkpoint_every: u64,
    /// Keep only the newest N `state-<iter>` checkpoint dirs after each
    /// successful save (0 = never prune).
    pub keep_checkpoints: u64,
    /// Divergence watchdog master switch (only arms for policies that can
    /// escalate — static baselines keep their divergence behaviour).
    pub watchdog: bool,
    /// Watchdog: trip when finite loss exceeds this multiple of its EWMA.
    pub loss_explode_ratio: f64,
    /// Watchdog: finite-loss observations before the ratio rule arms.
    pub watchdog_warmup: u64,
    /// Watchdog: per-class overflow rate considered saturating.
    pub overflow_trip: f64,
    /// Watchdog: consecutive saturating iterations before tripping.
    pub overflow_window: u64,
    /// Rollback/escalation attempts before the run aborts.
    pub max_recoveries: u64,
    /// Post-rollback grace, in iterations (doubles per retry).
    pub recovery_backoff: u64,
    /// Resume from the newest complete checkpoint in `checkpoint_dir`.
    pub resume: bool,
    /// Fault-injection specs (see [`crate::resilience::parse_spec`]).
    pub faults: Vec<String>,
    /// Seed for fault-site selection (independent of the data seed).
    pub fault_seed: u64,
    /// Keep parameters/momenta device-resident between steps (zero
    /// steady-state host↔device state transfers).  `false` forces the
    /// host-literal path; the engine also falls back automatically when the
    /// platform can't execute against device buffers.
    pub device_params: bool,
    /// Precompute the eval batches once per test set (pinned x/y literals +
    /// tail-mask counts, uploaded to resident device buffers on the
    /// device-params path) so steady-state eval passes perform zero host
    /// batch prep and zero input uploads.  `false` forces the legacy
    /// per-batch refill path (the A/B baseline for `repro bench eval`).
    pub eval_set: bool,
    /// Stream telemetry span/counter events to this JSONL file during the
    /// run (`--trace` / `telemetry.trace_path`); `None` disables the sink.
    pub trace_path: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        let opts = PolicyOptions::default();
        Self {
            model: "lenet".into(),
            scheme: "qedps".into(),
            iters: 3000,
            lr0: 0.01,
            gamma: 1e-4,
            power: 0.75,
            e_max: 1e-4,
            r_max: 1e-4,
            init_weights: opts.init.weights,
            init_acts: opts.init.acts,
            init_grads: opts.init.grads,
            agg: AggMode::Mean,
            train_n: 10_000,
            test_n: 2_000,
            seed: 2018,
            eval_every: 500,
            log_every: 50,
            force_rounding: None,
            out_dir: "target/experiments".into(),
            checkpoint_dir: None,
            checkpoint_every: 1000,
            keep_checkpoints: 3,
            watchdog: true,
            loss_explode_ratio: 4.0,
            watchdog_warmup: 20,
            overflow_trip: 0.25,
            overflow_window: 8,
            max_recoveries: 3,
            recovery_backoff: 50,
            resume: false,
            faults: Vec::new(),
            fault_seed: 7,
            device_params: true,
            eval_set: true,
            trace_path: None,
        }
    }
}

impl ExperimentConfig {
    /// Paper learning-rate schedule: `lr = lr0 * (1 + gamma*iter)^-power`.
    pub fn lr_at(&self, iter: u64) -> f64 {
        self.lr0 * (1.0 + self.gamma * iter as f64).powf(-self.power)
    }

    pub fn policy_options(&self) -> PolicyOptions {
        PolicyOptions {
            e_max: self.e_max as f32,
            r_max: self.r_max as f32,
            init: PrecState {
                weights: self.init_weights,
                acts: self.init_acts,
                grads: self.init_grads,
            },
        }
    }

    /// Load from a TOML file and fold it over the defaults.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let doc = toml::parse(&text).with_context(|| format!("parsing {path}"))?;
        let mut cfg = Self::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    pub fn apply_doc(&mut self, doc: &TomlDoc) -> Result<()> {
        for (section, table) in doc {
            for (key, val) in table {
                let path = if section.is_empty() {
                    key.clone()
                } else {
                    format!("{section}.{key}")
                };
                self.apply_kv(&path, val)?;
            }
        }
        Ok(())
    }

    /// Apply one dotted-path override (shared by TOML and `--set k=v`).
    pub fn apply_kv(&mut self, key: &str, val: &TomlValue) -> Result<()> {
        let want_str =
            || -> Result<String> { Ok(val.as_str().context("expected string")?.into()) };
        let want_f = || val.as_f64().context("expected number");
        let want_u = || -> Result<u64> { Ok(val.as_f64().context("expected int")? as u64) };
        let want_fmt = || -> Result<Format> {
            match val {
                TomlValue::Arr(v) if v.len() == 2 => Ok(Format::new(
                    v[0].as_f64().context("IL")? as i32,
                    v[1].as_f64().context("FL")? as i32,
                )),
                _ => bail!("expected [IL, FL] pair"),
            }
        };
        match key {
            "model" => self.model = want_str()?,
            "scheme" => self.scheme = want_str()?,
            "iters" => self.iters = want_u()?,
            "lr0" => self.lr0 = want_f()?,
            "gamma" => self.gamma = want_f()?,
            "power" => self.power = want_f()?,
            "policy.e_max" | "e_max" => self.e_max = want_f()?,
            "policy.r_max" | "r_max" => self.r_max = want_f()?,
            "policy.init_weights" | "init_weights" => self.init_weights = want_fmt()?,
            "policy.init_acts" | "init_acts" => self.init_acts = want_fmt()?,
            "policy.init_grads" | "init_grads" => self.init_grads = want_fmt()?,
            "policy.agg" | "agg" => {
                self.agg = AggMode::from_str(val.as_str().unwrap_or(""))
                    .context("agg must be mean|max|last")?
            }
            "data.train_n" | "train_n" => self.train_n = want_u()? as usize,
            "data.test_n" | "test_n" => self.test_n = want_u()? as usize,
            "seed" | "data.seed" => self.seed = want_u()?,
            "eval_every" => self.eval_every = want_u()?,
            "log_every" => self.log_every = want_u()?,
            "out_dir" => self.out_dir = want_str()?,
            "force_rounding" => self.force_rounding = Some(want_str()?),
            "checkpoint.dir" | "checkpoint_dir" => self.checkpoint_dir = Some(want_str()?),
            "checkpoint.every" | "checkpoint_every" => self.checkpoint_every = want_u()?,
            "resilience.keep_checkpoints" | "checkpoint.keep" | "keep_checkpoints" => {
                self.keep_checkpoints = want_u()?
            }
            "resilience.watchdog" | "watchdog" => {
                self.watchdog = val.as_bool().context("expected bool")?
            }
            "resilience.loss_ratio" | "loss_explode_ratio" => {
                self.loss_explode_ratio = want_f()?
            }
            "resilience.warmup" | "watchdog_warmup" => self.watchdog_warmup = want_u()?,
            "resilience.r_trip" | "overflow_trip" => self.overflow_trip = want_f()?,
            "resilience.r_window" | "overflow_window" => self.overflow_window = want_u()?,
            "resilience.max_retries" | "max_recoveries" => self.max_recoveries = want_u()?,
            "resilience.backoff" | "recovery_backoff" => self.recovery_backoff = want_u()?,
            "resilience.resume" | "resume" => {
                self.resume = val.as_bool().context("expected bool")?
            }
            "faults.inject" | "faults" => match val {
                TomlValue::Str(s) => self.faults.push(s.clone()),
                TomlValue::Arr(items) => {
                    for it in items {
                        self.faults.push(
                            it.as_str().context("faults entries must be strings")?.into(),
                        );
                    }
                }
                _ => bail!("faults.inject takes a spec string or array of specs"),
            },
            "faults.seed" | "fault_seed" => self.fault_seed = want_u()?,
            "runtime.device_params" | "device_params" => {
                self.device_params = val.as_bool().context("expected bool")?
            }
            "runtime.eval_set" | "eval_set" => {
                self.eval_set = val.as_bool().context("expected bool")?
            }
            "telemetry.trace_path" | "trace_path" => self.trace_path = Some(want_str()?),
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Parse `k=v` (CLI `--set`) using TOML value syntax for `v`.
    pub fn apply_set(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .with_context(|| format!("--set needs key=value, got '{kv}'"))?;
        let doc = toml::parse(&format!("x = {}", v.trim()))?;
        self.apply_kv(k.trim(), &doc[""]["x"])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_paper_settings() {
        let c = ExperimentConfig::default();
        assert_eq!(c.lr0, 0.01);
        assert_eq!(c.gamma, 1e-4);
        assert_eq!(c.power, 0.75);
        assert_eq!(c.e_max, 1e-4);
        assert_eq!(c.r_max, 1e-4);
        assert_eq!(c.keep_checkpoints, 3, "checkpoint GC defaults to keep-3");
    }

    #[test]
    fn keep_checkpoints_aliases() {
        let mut c = ExperimentConfig::default();
        c.apply_set("checkpoint.keep=0").unwrap();
        assert_eq!(c.keep_checkpoints, 0);
        c.apply_set("keep_checkpoints=7").unwrap();
        assert_eq!(c.keep_checkpoints, 7);
    }

    #[test]
    fn lr_schedule_matches_formula() {
        let c = ExperimentConfig::default();
        assert!((c.lr_at(0) - 0.01).abs() < 1e-12);
        let lr10k = 0.01 * (1.0f64 + 1e-4 * 10_000.0).powf(-0.75);
        assert!((c.lr_at(10_000) - lr10k).abs() < 1e-12);
        assert!(c.lr_at(10_000) < c.lr_at(0));
    }

    #[test]
    fn toml_overrides() {
        let doc = toml::parse(
            r#"
            scheme = "na"
            iters = 100
            [policy]
            e_max = 0.5
            init_weights = [8, 8]
            agg = "max"
            "#,
        )
        .unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.scheme, "na");
        assert_eq!(c.iters, 100);
        assert_eq!(c.e_max, 0.5);
        assert_eq!(c.init_weights, Format::new(8, 8));
        assert_eq!(c.agg, AggMode::Max);
    }

    #[test]
    fn resilience_section_parses() {
        let doc = toml::parse(
            r#"
            [resilience]
            watchdog = false
            loss_ratio = 6.0
            warmup = 10
            r_trip = 0.5
            r_window = 4
            max_retries = 5
            backoff = 25
            resume = true
            keep_checkpoints = 5
            [faults]
            inject = ["nan@12", "bitflip@3:grad"]
            seed = 99
            "#,
        )
        .unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_doc(&doc).unwrap();
        assert!(!c.watchdog);
        assert_eq!(c.loss_explode_ratio, 6.0);
        assert_eq!(c.watchdog_warmup, 10);
        assert_eq!(c.overflow_trip, 0.5);
        assert_eq!(c.overflow_window, 4);
        assert_eq!(c.max_recoveries, 5);
        assert_eq!(c.recovery_backoff, 25);
        assert!(c.resume);
        assert_eq!(c.keep_checkpoints, 5);
        assert_eq!(c.faults, vec!["nan@12".to_string(), "bitflip@3:grad".to_string()]);
        assert_eq!(c.fault_seed, 99);
    }

    #[test]
    fn fault_specs_accumulate_from_set() {
        let mut c = ExperimentConfig::default();
        c.apply_set("faults=\"nan@5\"").unwrap();
        c.apply_set("faults=\"inf@9\"").unwrap();
        assert_eq!(c.faults, vec!["nan@5".to_string(), "inf@9".to_string()]);
        assert!(c.apply_set("faults=3").is_err());
        assert!(c.apply_set("watchdog=1").is_err(), "watchdog wants a bool");
        c.apply_set("watchdog=false").unwrap();
        assert!(!c.watchdog);
    }

    #[test]
    fn device_params_flag() {
        let mut c = ExperimentConfig::default();
        assert!(c.device_params, "device residency is the default");
        c.apply_set("runtime.device_params=false").unwrap();
        assert!(!c.device_params);
        c.apply_set("device_params=true").unwrap();
        assert!(c.device_params);
        assert!(c.apply_set("device_params=1").is_err(), "wants a bool");
    }

    #[test]
    fn eval_set_flag() {
        let mut c = ExperimentConfig::default();
        assert!(c.eval_set, "the precomputed eval set is the default");
        c.apply_set("runtime.eval_set=false").unwrap();
        assert!(!c.eval_set);
        c.apply_set("eval_set=true").unwrap();
        assert!(c.eval_set);
        assert!(c.apply_set("eval_set=1").is_err(), "wants a bool");
    }

    #[test]
    fn trace_path_key() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.trace_path, None, "tracing is off by default");
        c.apply_set("telemetry.trace_path=\"target/t.jsonl\"").unwrap();
        assert_eq!(c.trace_path.as_deref(), Some("target/t.jsonl"));
        c.apply_set("trace_path=\"other.jsonl\"").unwrap();
        assert_eq!(c.trace_path.as_deref(), Some("other.jsonl"));
        assert!(c.apply_set("trace_path=3").is_err(), "wants a string");
    }

    #[test]
    fn set_overrides() {
        let mut c = ExperimentConfig::default();
        c.apply_set("scheme=\"float\"").unwrap();
        c.apply_set("iters = 7").unwrap();
        c.apply_set("init_acts = [3, 5]").unwrap();
        assert_eq!(c.scheme, "float");
        assert_eq!(c.iters, 7);
        assert_eq!(c.init_acts, Format::new(3, 5));
        assert!(c.apply_set("bogus=1").is_err());
        assert!(c.apply_set("no_equals").is_err());
    }
}
