//! MNIST IDX file-format loader (LeCun's original binary layout), with
//! transparent `.gz` support via flate2.
//!
//! IDX format: big-endian magic (2 zero bytes, type code, ndim), then one
//! u32 per dimension, then raw data.  Images are `0x08` (u8) with 3 dims
//! `(n, 28, 28)`; labels are `0x08` with 1 dim.

use std::io::Read;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{Dataset, IMG_SIDE};

fn read_maybe_gz(path: &Path) -> Result<Vec<u8>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if raw.len() >= 2 && raw[0] == 0x1f && raw[1] == 0x8b {
        let mut out = Vec::new();
        flate2::read::GzDecoder::new(&raw[..])
            .read_to_end(&mut out)
            .with_context(|| format!("gunzip {path:?}"))?;
        Ok(out)
    } else {
        Ok(raw)
    }
}

fn be_u32(b: &[u8], off: usize) -> Result<u32> {
    if off + 4 > b.len() {
        bail!("idx: truncated header");
    }
    Ok(u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]]))
}

/// Parse an IDX byte buffer into (dims, data).
pub fn parse_idx(buf: &[u8]) -> Result<(Vec<usize>, &[u8])> {
    if buf.len() < 4 || buf[0] != 0 || buf[1] != 0 {
        bail!("idx: bad magic");
    }
    if buf[2] != 0x08 {
        bail!("idx: only u8 data supported (type 0x{:02x})", buf[2]);
    }
    let ndim = buf[3] as usize;
    let mut dims = Vec::with_capacity(ndim);
    for d in 0..ndim {
        dims.push(be_u32(buf, 4 + 4 * d)? as usize);
    }
    let start = 4 + 4 * ndim;
    let total: usize = dims.iter().product();
    if buf.len() < start + total {
        bail!("idx: truncated data ({} < {})", buf.len() - start, total);
    }
    Ok((dims, &buf[start..start + total]))
}

fn load_images(path: &Path) -> Result<Vec<f32>> {
    let buf = read_maybe_gz(path)?;
    let (dims, data) = parse_idx(&buf)?;
    if dims.len() != 3 || dims[1] != IMG_SIDE || dims[2] != IMG_SIDE {
        bail!("idx: expected (n,28,28) images, got {dims:?}");
    }
    Ok(data.iter().map(|&b| b as f32 / 255.0).collect())
}

fn load_labels(path: &Path) -> Result<Vec<u8>> {
    let buf = read_maybe_gz(path)?;
    let (dims, data) = parse_idx(&buf)?;
    if dims.len() != 1 {
        bail!("idx: expected 1-d labels, got {dims:?}");
    }
    Ok(data.to_vec())
}

fn find(dir: &Path, names: &[&str]) -> Result<PathBuf> {
    for n in names {
        for ext in ["", ".gz"] {
            let p = dir.join(format!("{n}{ext}"));
            if p.exists() {
                return Ok(p);
            }
        }
    }
    bail!("none of {names:?} found in {dir:?}")
}

/// Load the canonical 4-file train/test pair from a directory.
pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<(Dataset, Dataset)> {
    let dir = dir.as_ref();
    let tr_x = load_images(&find(dir, &["train-images-idx3-ubyte", "train-images.idx3-ubyte"])?)?;
    let tr_y = load_labels(&find(dir, &["train-labels-idx1-ubyte", "train-labels.idx1-ubyte"])?)?;
    let te_x = load_images(&find(dir, &["t10k-images-idx3-ubyte", "t10k-images.idx3-ubyte"])?)?;
    let te_y = load_labels(&find(dir, &["t10k-labels-idx1-ubyte", "t10k-labels.idx1-ubyte"])?)?;
    Ok((Dataset::new(tr_x, tr_y), Dataset::new(te_x, te_y)))
}

/// Serialize a dataset back to IDX (used by tests and `repro gen-data`).
pub fn write_idx_images(path: &Path, ds: &Dataset) -> Result<()> {
    let mut out = vec![0u8, 0, 0x08, 3];
    out.extend((ds.n as u32).to_be_bytes());
    out.extend((IMG_SIDE as u32).to_be_bytes());
    out.extend((IMG_SIDE as u32).to_be_bytes());
    out.extend(ds.images.iter().map(|&f| (f * 255.0).round().clamp(0.0, 255.0) as u8));
    std::fs::write(path, out)?;
    Ok(())
}

pub fn write_idx_labels(path: &Path, ds: &Dataset) -> Result<()> {
    let mut out = vec![0u8, 0, 0x08, 1];
    out.extend((ds.n as u32).to_be_bytes());
    out.extend_from_slice(&ds.labels);
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_idx(&[]).is_err());
        assert!(parse_idx(&[1, 2, 3, 4]).is_err());
        assert!(parse_idx(&[0, 0, 0x09, 1, 0, 0, 0, 1, 7]).is_err()); // type
        assert!(parse_idx(&[0, 0, 0x08, 1, 0, 0, 0, 9, 1]).is_err()); // short
    }

    #[test]
    fn parse_minimal() {
        let buf = [0, 0, 0x08, 1, 0, 0, 0, 3, 10, 20, 30];
        let (dims, data) = parse_idx(&buf).unwrap();
        assert_eq!(dims, vec![3]);
        assert_eq!(data, &[10, 20, 30]);
    }

    #[test]
    fn roundtrip_via_files() {
        let ds = synth::generate(32, 7);
        let dir = std::env::temp_dir().join("qedps_mnist_rt");
        std::fs::create_dir_all(&dir).unwrap();
        write_idx_images(&dir.join("train-images-idx3-ubyte"), &ds).unwrap();
        write_idx_labels(&dir.join("train-labels-idx1-ubyte"), &ds).unwrap();
        write_idx_images(&dir.join("t10k-images-idx3-ubyte"), &ds).unwrap();
        write_idx_labels(&dir.join("t10k-labels-idx1-ubyte"), &ds).unwrap();
        let (train, test) = load_dir(&dir).unwrap();
        assert_eq!(train.n, 32);
        assert_eq!(test.labels, ds.labels);
        // u8 quantization: within half a step
        for (a, b) in train.images.iter().zip(&ds.images) {
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6);
        }
    }

    #[test]
    fn gz_transparent() {
        use std::io::Write;
        let ds = synth::generate(4, 9);
        let dir = std::env::temp_dir().join("qedps_mnist_gz");
        std::fs::create_dir_all(&dir).unwrap();
        let plain = dir.join("labels.idx");
        write_idx_labels(&plain, &ds).unwrap();
        let raw = std::fs::read(&plain).unwrap();
        let gz_path = dir.join("labels.idx.gz");
        let mut enc = flate2::write::GzEncoder::new(
            std::fs::File::create(&gz_path).unwrap(),
            flate2::Compression::default(),
        );
        enc.write_all(&raw).unwrap();
        enc.finish().unwrap();
        let via_gz = read_maybe_gz(&gz_path).unwrap();
        assert_eq!(via_gz, raw);
    }
}
