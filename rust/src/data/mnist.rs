//! MNIST IDX file-format loader (LeCun's original binary layout), with
//! transparent `.gz` support via flate2.
//!
//! IDX format: big-endian magic (2 zero bytes, type code, ndim), then one
//! u32 per dimension, then raw data.  Images are `0x08` (u8) with 3 dims
//! `(n, 28, 28)`; labels are `0x08` with 1 dim.

use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::{Dataset, IMG_SIDE};

/// Read a file, transparently gunzipping when the 2-byte gzip magic is
/// present.  The decoder streams straight off a buffered file handle, so
/// peak memory is one decompressed buffer — not raw + decompressed at once.
fn read_maybe_gz(path: &Path) -> Result<Vec<u8>> {
    let mut file = std::fs::File::open(path).with_context(|| format!("reading {path:?}"))?;
    let mut magic = [0u8; 2];
    let sniffed = file
        .read(&mut magic)
        .with_context(|| format!("reading {path:?}"))?;
    file.seek(SeekFrom::Start(0))
        .with_context(|| format!("reading {path:?}"))?;
    let mut out = Vec::new();
    if sniffed == 2 && magic == [0x1f, 0x8b] {
        flate2::read::GzDecoder::new(BufReader::new(file))
            .read_to_end(&mut out)
            .with_context(|| format!("gunzip {path:?}"))?;
    } else {
        BufReader::new(file)
            .read_to_end(&mut out)
            .with_context(|| format!("reading {path:?}"))?;
    }
    Ok(out)
}

fn be_u32(b: &[u8], off: usize) -> Result<u32> {
    if off + 4 > b.len() {
        bail!("idx: truncated header at byte {off} (file is {} bytes)", b.len());
    }
    Ok(u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]]))
}

/// Parse an IDX byte buffer into (dims, data).
pub fn parse_idx(buf: &[u8]) -> Result<(Vec<usize>, &[u8])> {
    if buf.len() < 4 || buf[0] != 0 || buf[1] != 0 {
        bail!("idx: bad magic at byte 0 (got {:02x?})", &buf[..buf.len().min(4)]);
    }
    if buf[2] != 0x08 {
        bail!("idx: only u8 data supported (type 0x{:02x} at byte 2)", buf[2]);
    }
    let ndim = buf[3] as usize;
    let mut dims = Vec::with_capacity(ndim);
    for d in 0..ndim {
        dims.push(be_u32(buf, 4 + 4 * d)? as usize);
    }
    let start = 4 + 4 * ndim;
    let total: usize = dims
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .with_context(|| format!("idx: dimension product overflows ({dims:?})"))?;
    if buf.len() < start + total {
        bail!(
            "idx: truncated data at byte {start}: {} bytes present, {total} \
             expected from dims {dims:?}",
            buf.len() - start
        );
    }
    Ok((dims, &buf[start..start + total]))
}

fn load_images(path: &Path) -> Result<Vec<f32>> {
    let buf = read_maybe_gz(path)?;
    let (dims, data) =
        parse_idx(&buf).with_context(|| format!("parsing images {path:?}"))?;
    if dims.len() != 3 || dims[1] != IMG_SIDE || dims[2] != IMG_SIDE {
        bail!("{path:?}: expected (n,28,28) images, got {dims:?}");
    }
    Ok(data.iter().map(|&b| b as f32 / 255.0).collect())
}

fn load_labels(path: &Path) -> Result<Vec<u8>> {
    let buf = read_maybe_gz(path)?;
    let (dims, data) =
        parse_idx(&buf).with_context(|| format!("parsing labels {path:?}"))?;
    if dims.len() != 1 {
        bail!("{path:?}: expected 1-d labels, got {dims:?}");
    }
    Ok(data.to_vec())
}

fn find_opt(dir: &Path, names: &[&str]) -> Option<PathBuf> {
    for n in names {
        for ext in ["", ".gz"] {
            let p = dir.join(format!("{n}{ext}"));
            if p.exists() {
                return Some(p);
            }
        }
    }
    None
}

fn find(dir: &Path, names: &[&str]) -> Result<PathBuf> {
    find_opt(dir, names).with_context(|| format!("none of {names:?} found in {dir:?}"))
}

const TRAIN_IMAGES: &[&str] = &["train-images-idx3-ubyte", "train-images.idx3-ubyte"];
const TRAIN_LABELS: &[&str] = &["train-labels-idx1-ubyte", "train-labels.idx1-ubyte"];
const TEST_IMAGES: &[&str] = &["t10k-images-idx3-ubyte", "t10k-images.idx3-ubyte"];
const TEST_LABELS: &[&str] = &["t10k-labels-idx1-ubyte", "t10k-labels.idx1-ubyte"];

/// Load the canonical 4-file train/test pair from a directory.
pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<(Dataset, Dataset)> {
    let dir = dir.as_ref();
    let tr_x = load_images(&find(dir, TRAIN_IMAGES)?)?;
    let tr_y = load_labels(&find(dir, TRAIN_LABELS)?)?;
    let te_x = load_images(&find(dir, TEST_IMAGES)?)?;
    let te_y = load_labels(&find(dir, TEST_LABELS)?)?;
    if tr_x.len() != tr_y.len() * crate::data::IMG_PIXELS {
        bail!(
            "{dir:?}: train images/labels disagree ({} pixels vs {} labels)",
            tr_x.len(),
            tr_y.len()
        );
    }
    if te_x.len() != te_y.len() * crate::data::IMG_PIXELS {
        bail!(
            "{dir:?}: test images/labels disagree ({} pixels vs {} labels)",
            te_x.len(),
            te_y.len()
        );
    }
    Ok((Dataset::new(tr_x, tr_y), Dataset::new(te_x, te_y)))
}

/// Distinguish "MNIST is absent" (`Ok(None)` — the normal offline case)
/// from "MNIST is present but unreadable" (`Err` — the caller should warn
/// loudly before falling back, since training silently on synthetic data
/// when the user staged real MNIST would invalidate their run).
pub fn try_load_dir<P: AsRef<Path>>(dir: P) -> Result<Option<(Dataset, Dataset)>> {
    let dir = dir.as_ref();
    let any_present = [TRAIN_IMAGES, TRAIN_LABELS, TEST_IMAGES, TEST_LABELS]
        .iter()
        .any(|names| find_opt(dir, names).is_some());
    if !any_present {
        return Ok(None);
    }
    load_dir(dir).map(Some)
}

/// Serialize a dataset back to IDX (used by tests and `repro gen-data`).
pub fn write_idx_images(path: &Path, ds: &Dataset) -> Result<()> {
    let mut out = vec![0u8, 0, 0x08, 3];
    out.extend((ds.n as u32).to_be_bytes());
    out.extend((IMG_SIDE as u32).to_be_bytes());
    out.extend((IMG_SIDE as u32).to_be_bytes());
    out.extend(ds.images.iter().map(|&f| (f * 255.0).round().clamp(0.0, 255.0) as u8));
    std::fs::write(path, out)?;
    Ok(())
}

pub fn write_idx_labels(path: &Path, ds: &Dataset) -> Result<()> {
    let mut out = vec![0u8, 0, 0x08, 1];
    out.extend((ds.n as u32).to_be_bytes());
    out.extend_from_slice(&ds.labels);
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_idx(&[]).is_err());
        assert!(parse_idx(&[1, 2, 3, 4]).is_err());
        assert!(parse_idx(&[0, 0, 0x09, 1, 0, 0, 0, 1, 7]).is_err()); // type
        assert!(parse_idx(&[0, 0, 0x08, 1, 0, 0, 0, 9, 1]).is_err()); // short
    }

    #[test]
    fn parse_minimal() {
        let buf = [0, 0, 0x08, 1, 0, 0, 0, 3, 10, 20, 30];
        let (dims, data) = parse_idx(&buf).unwrap();
        assert_eq!(dims, vec![3]);
        assert_eq!(data, &[10, 20, 30]);
    }

    #[test]
    fn roundtrip_via_files() {
        let ds = synth::generate(32, 7);
        let dir = std::env::temp_dir().join("qedps_mnist_rt");
        std::fs::create_dir_all(&dir).unwrap();
        write_idx_images(&dir.join("train-images-idx3-ubyte"), &ds).unwrap();
        write_idx_labels(&dir.join("train-labels-idx1-ubyte"), &ds).unwrap();
        write_idx_images(&dir.join("t10k-images-idx3-ubyte"), &ds).unwrap();
        write_idx_labels(&dir.join("t10k-labels-idx1-ubyte"), &ds).unwrap();
        let (train, test) = load_dir(&dir).unwrap();
        assert_eq!(train.n, 32);
        assert_eq!(test.labels, ds.labels);
        // u8 quantization: within half a step
        for (a, b) in train.images.iter().zip(&ds.images) {
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6);
        }
    }

    #[test]
    fn try_load_distinguishes_absent_from_unreadable() {
        // absent: directory doesn't exist at all
        let absent = std::env::temp_dir().join("qedps_mnist_no_such_dir");
        let _ = std::fs::remove_dir_all(&absent);
        assert!(try_load_dir(&absent).unwrap().is_none());

        // absent: directory exists but holds no IDX files
        let empty = std::env::temp_dir().join("qedps_mnist_empty");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(try_load_dir(&empty).unwrap().is_none());

        // unreadable: a train-images file exists but is garbage
        let bad = std::env::temp_dir().join("qedps_mnist_bad");
        std::fs::create_dir_all(&bad).unwrap();
        std::fs::write(bad.join("train-images-idx3-ubyte"), b"not idx").unwrap();
        let err = try_load_dir(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("train-images"), "{err:#}");

        // partial: images present, labels missing — also an error, not a
        // silent fallback
        let partial = std::env::temp_dir().join("qedps_mnist_partial");
        let _ = std::fs::remove_dir_all(&partial);
        std::fs::create_dir_all(&partial).unwrap();
        let ds = synth::generate(4, 11);
        write_idx_images(&partial.join("train-images-idx3-ubyte"), &ds).unwrap();
        assert!(try_load_dir(&partial).is_err());
    }

    #[test]
    fn try_load_accepts_complete_set() {
        let ds = synth::generate(8, 5);
        let dir = std::env::temp_dir().join("qedps_mnist_ok");
        std::fs::create_dir_all(&dir).unwrap();
        write_idx_images(&dir.join("train-images-idx3-ubyte"), &ds).unwrap();
        write_idx_labels(&dir.join("train-labels-idx1-ubyte"), &ds).unwrap();
        write_idx_images(&dir.join("t10k-images-idx3-ubyte"), &ds).unwrap();
        write_idx_labels(&dir.join("t10k-labels-idx1-ubyte"), &ds).unwrap();
        let (train, _test) = try_load_dir(&dir).unwrap().expect("complete set loads");
        assert_eq!(train.n, 8);
    }

    #[test]
    fn parse_rejects_dim_overflow() {
        // three dims whose product overflows even 64-bit usize
        let mut buf = vec![0u8, 0, 0x08, 3];
        for _ in 0..3 {
            buf.extend(u32::MAX.to_be_bytes());
        }
        let err = parse_idx(&buf).unwrap_err();
        assert!(format!("{err:#}").contains("overflow"), "{err:#}");
    }

    #[test]
    fn gz_transparent() {
        use std::io::Write;
        let ds = synth::generate(4, 9);
        let dir = std::env::temp_dir().join("qedps_mnist_gz");
        std::fs::create_dir_all(&dir).unwrap();
        let plain = dir.join("labels.idx");
        write_idx_labels(&plain, &ds).unwrap();
        let raw = std::fs::read(&plain).unwrap();
        let gz_path = dir.join("labels.idx.gz");
        let mut enc = flate2::write::GzEncoder::new(
            std::fs::File::create(&gz_path).unwrap(),
            flate2::Compression::default(),
        );
        enc.write_all(&raw).unwrap();
        enc.finish().unwrap();
        let via_gz = read_maybe_gz(&gz_path).unwrap();
        assert_eq!(via_gz, raw);
    }

    #[test]
    fn read_maybe_gz_handles_tiny_files() {
        // shorter than the 2-byte magic sniff: must come back verbatim
        let dir = std::env::temp_dir().join("qedps_mnist_tiny");
        std::fs::create_dir_all(&dir).unwrap();
        for (name, bytes) in [("empty", &b""[..]), ("one", &b"\x1f"[..])] {
            let p = dir.join(name);
            std::fs::write(&p, bytes).unwrap();
            assert_eq!(read_maybe_gz(&p).unwrap(), bytes);
        }
    }
}
