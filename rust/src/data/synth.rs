//! Synthetic handwritten-digit generator — the offline MNIST substitute
//! (DESIGN.md substitution #2).
//!
//! Each class is a stroke skeleton (polyline/arc control points in a unit
//! box).  A sample applies a random affine jitter (rotation, anisotropic
//! scale, shear, translation), renders the strokes with a random pen width
//! via distance-to-segment antialiasing, then adds mild pixel noise — the
//! same axes of variation that make MNIST non-trivial.  A LeNet float
//! baseline reaches high-90s% accuracy; the relative behaviour of the
//! precision schemes (which is what the paper's figures compare) carries
//! over.

use crate::util::rng::Pcg32;

use super::{Dataset, IMG_PIXELS, IMG_SIDE};

type Pt = (f32, f32);

/// Sample an arc as a polyline. Angles in turns (1.0 = full circle).
fn arc(cx: f32, cy: f32, rx: f32, ry: f32, a0: f32, a1: f32, n: usize) -> Vec<Pt> {
    (0..=n)
        .map(|i| {
            let t = a0 + (a1 - a0) * i as f32 / n as f32;
            let rad = t * std::f32::consts::TAU;
            (cx + rx * rad.cos(), cy - ry * rad.sin())
        })
        .collect()
}

/// Stroke skeletons per digit, in a [0,1]^2 box (y grows downward).
fn skeleton(digit: u8) -> Vec<Vec<Pt>> {
    match digit {
        0 => vec![arc(0.5, 0.5, 0.32, 0.42, 0.0, 1.0, 24)],
        1 => vec![vec![(0.35, 0.25), (0.55, 0.08), (0.55, 0.92)]],
        2 => vec![{
            let mut s = arc(0.5, 0.28, 0.26, 0.2, 0.5, -0.08, 12);
            s.extend([(0.22, 0.9), (0.8, 0.9)]);
            s
        }],
        3 => vec![
            arc(0.45, 0.28, 0.26, 0.2, 0.55, -0.25, 12),
            arc(0.45, 0.7, 0.3, 0.22, 0.25, -0.55, 12),
        ],
        4 => vec![
            vec![(0.62, 0.08), (0.18, 0.62), (0.85, 0.62)],
            vec![(0.62, 0.3), (0.62, 0.95)],
        ],
        5 => vec![{
            let mut s = vec![(0.75, 0.1), (0.3, 0.1), (0.27, 0.45)];
            s.extend(arc(0.48, 0.68, 0.28, 0.24, 0.3, -0.45, 14));
            s
        }],
        6 => vec![{
            let mut s = arc(0.52, 0.3, 0.3, 0.26, 0.45, 0.25, 8);
            s.extend(arc(0.5, 0.68, 0.26, 0.24, 0.25, -0.75, 16));
            s
        }],
        7 => vec![vec![(0.2, 0.12), (0.8, 0.12), (0.42, 0.92)]],
        8 => vec![
            arc(0.5, 0.3, 0.24, 0.2, 0.0, 1.0, 16),
            arc(0.5, 0.72, 0.28, 0.22, 0.0, 1.0, 16),
        ],
        9 => vec![
            arc(0.52, 0.32, 0.26, 0.22, 0.0, 1.0, 16),
            vec![(0.78, 0.32), (0.72, 0.92)],
        ],
        _ => panic!("digit out of range"),
    }
}

struct Affine {
    a: f32,
    b: f32,
    c: f32,
    d: f32,
    tx: f32,
    ty: f32,
}

impl Affine {
    fn random(rng: &mut Pcg32) -> Self {
        let rot = (rng.next_f32() - 0.5) * 0.5; // +/- ~14 deg
        let (sin, cos) = rot.sin_cos();
        let sx = 0.75 + rng.next_f32() * 0.4;
        let sy = 0.75 + rng.next_f32() * 0.4;
        let shear = (rng.next_f32() - 0.5) * 0.35;
        let tx = (rng.next_f32() - 0.5) * 0.2;
        let ty = (rng.next_f32() - 0.5) * 0.16;
        Self {
            a: sx * cos,
            b: -sy * sin + shear * cos,
            c: sx * sin,
            d: sy * cos + shear * sin,
            tx,
            ty,
        }
    }

    fn apply(&self, p: Pt) -> Pt {
        // transform about the glyph centre (0.5, 0.5)
        let (x, y) = (p.0 - 0.5, p.1 - 0.5);
        (
            self.a * x + self.b * y + 0.5 + self.tx,
            self.c * x + self.d * y + 0.5 + self.ty,
        )
    }
}

fn dist_to_segment(p: Pt, a: Pt, b: Pt) -> f32 {
    let (vx, vy) = (b.0 - a.0, b.1 - a.1);
    let (wx, wy) = (p.0 - a.0, p.1 - a.1);
    let len2 = vx * vx + vy * vy;
    let t = if len2 > 1e-12 {
        ((wx * vx + wy * vy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (dx, dy) = (wx - t * vx, wy - t * vy);
    (dx * dx + dy * dy).sqrt()
}

/// Render one digit into `out` (28*28, overwritten).
pub fn render(digit: u8, rng: &mut Pcg32, out: &mut [f32]) {
    assert_eq!(out.len(), IMG_PIXELS);
    let aff = Affine::random(rng);
    let strokes: Vec<Vec<Pt>> = skeleton(digit)
        .into_iter()
        .map(|s| s.into_iter().map(|p| aff.apply(p)).collect())
        .collect();
    let pen = 0.035 + rng.next_f32() * 0.03; // stroke radius in unit coords
    let noise_amp = 0.04 + rng.next_f32() * 0.04;

    // Collect segments once.
    let mut segs: Vec<(Pt, Pt)> = Vec::new();
    for s in &strokes {
        for w in s.windows(2) {
            segs.push((w[0], w[1]));
        }
    }

    for py in 0..IMG_SIDE {
        for px in 0..IMG_SIDE {
            // pixel centre in unit coords (2px margin like MNIST's frame)
            let fx = (px as f32 + 0.5) / IMG_SIDE as f32;
            let fy = (py as f32 + 0.5) / IMG_SIDE as f32;
            let mut d = f32::INFINITY;
            for &(a, b) in &segs {
                d = d.min(dist_to_segment((fx, fy), a, b));
                if d < 1e-4 {
                    break;
                }
            }
            // soft pen edge: full ink inside radius, ~1.5px falloff
            let edge = 1.5 / IMG_SIDE as f32;
            let ink = ((pen + edge - d) / edge).clamp(0.0, 1.0);
            let noise = (rng.next_f32() - 0.5) * noise_amp;
            out[py * IMG_SIDE + px] = (ink + noise * ink.max(0.1)).clamp(0.0, 1.0);
        }
    }
}

/// Generate a balanced, shuffled dataset of `n` samples.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::seeded(seed);
    let mut labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
    rng.shuffle(&mut labels);
    let mut images = vec![0.0f32; n * IMG_PIXELS];
    for (i, &l) in labels.iter().enumerate() {
        render(l, &mut rng, &mut images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]);
    }
    Dataset::new(images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_and_deterministic() {
        let a = generate(200, 3);
        let b = generate(200, 3);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
        for c in a.class_counts() {
            assert_eq!(c, 20);
        }
    }

    #[test]
    fn seeds_differ() {
        let a = generate(50, 1);
        let b = generate(50, 2);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn pixels_in_range_with_ink() {
        let ds = generate(100, 5);
        let mut ink = 0.0;
        for &p in &ds.images {
            assert!((0.0..=1.0).contains(&p));
            ink += p as f64;
        }
        let mean = ink / ds.images.len() as f64;
        // digits cover roughly 10-30% of the frame
        assert!((0.03..0.4).contains(&mean), "mean ink {mean}");
    }

    #[test]
    fn classes_are_visually_distinct() {
        // Nearest-centroid self-classification must beat chance by a lot —
        // a weak but implementation-independent signal that the generator
        // produces learnable classes.
        let train = generate(500, 11);
        let test = generate(200, 12);
        let mut centroids = vec![vec![0.0f64; IMG_PIXELS]; 10];
        let counts = train.class_counts();
        for i in 0..train.n {
            let l = train.labels[i] as usize;
            for (c, &p) in centroids[l].iter_mut().zip(train.image(i)) {
                *c += p as f64;
            }
        }
        for (c, n) in centroids.iter_mut().zip(counts) {
            for v in c.iter_mut() {
                *v /= n as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.n {
            let img = test.image(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = centroids[a]
                        .iter()
                        .zip(img)
                        .map(|(c, &p)| (c - p as f64).powi(2))
                        .sum();
                    let db: f64 = centroids[b]
                        .iter()
                        .zip(img)
                        .map(|(c, &p)| (c - p as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            correct += (best == test.labels[i] as usize) as usize;
        }
        let acc = correct as f64 / test.n as f64;
        assert!(acc > 0.6, "nearest-centroid acc {acc} too low");
    }

    #[test]
    fn every_digit_renders() {
        let mut rng = Pcg32::seeded(1);
        let mut buf = vec![0.0; IMG_PIXELS];
        for d in 0..10 {
            render(d, &mut rng, &mut buf);
            assert!(buf.iter().sum::<f32>() > 5.0, "digit {d} blank");
        }
    }
}
