//! Shuffled mini-batch iterator over a [`Dataset`].
//!
//! Epoch semantics match Caffe's data layer: a fresh permutation each
//! epoch, batches wrap across the epoch boundary so every batch has the
//! configured size.  Writes pixels/labels into caller-provided buffers so
//! the training hot loop performs no per-step allocation.

use super::{Dataset, IMG_PIXELS};
use crate::util::rng::Pcg32;

pub struct Batcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    order: Vec<u32>,
    pos: usize,
    rng: Pcg32,
    pub epochs: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, seed: u64) -> Self {
        assert!(batch > 0 && ds.n > 0);
        let mut rng = Pcg32::seeded(seed);
        let mut order: Vec<u32> = (0..ds.n as u32).collect();
        rng.shuffle(&mut order);
        Self { ds, batch, order, pos: 0, rng, epochs: 0 }
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Fill `x` (batch * IMG_PIXELS) and `y` (batch) with the next batch.
    pub fn next_into(&mut self, x: &mut [f32], y: &mut [i32]) {
        assert_eq!(x.len(), self.batch * IMG_PIXELS);
        assert_eq!(y.len(), self.batch);
        for b in 0..self.batch {
            if self.pos == self.order.len() {
                self.rng.shuffle(&mut self.order);
                self.pos = 0;
                self.epochs += 1;
            }
            let idx = self.order[self.pos] as usize;
            self.pos += 1;
            x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS]
                .copy_from_slice(self.ds.image(idx));
            y[b] = self.ds.labels[idx] as i32;
        }
    }

    /// Allocating convenience wrapper (tests, not the hot loop).
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<i32>) {
        let mut x = vec![0.0; self.batch * IMG_PIXELS];
        let mut y = vec![0; self.batch];
        self.next_into(&mut x, &mut y);
        (x, y)
    }
}

/// Deterministic sequential batches over a test set (no shuffle, exact
/// coverage; the tail batch is padded by wrapping to keep shapes static —
/// callers pass `valid` to weight the padded entries out).
pub struct EvalBatcher<'a> {
    ds: &'a Dataset,
    batch: usize,
    pos: usize,
}

impl<'a> EvalBatcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize) -> Self {
        Self { ds, batch, pos: 0 }
    }

    /// Number of batches covering the whole set.
    pub fn num_batches(&self) -> usize {
        self.ds.n.div_ceil(self.batch)
    }

    /// Fill the next batch; returns how many entries are valid (non-pad),
    /// or `None` when the set is exhausted.
    pub fn next_into(&mut self, x: &mut [f32], y: &mut [i32]) -> Option<usize> {
        if self.pos >= self.ds.n {
            return None;
        }
        let valid = (self.ds.n - self.pos).min(self.batch);
        for b in 0..self.batch {
            let idx = if b < valid { self.pos + b } else { (self.pos + b) % self.ds.n };
            x[b * IMG_PIXELS..(b + 1) * IMG_PIXELS]
                .copy_from_slice(self.ds.image(idx));
            y[b] = self.ds.labels[idx] as i32;
        }
        self.pos += valid;
        Some(valid)
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn covers_dataset_each_epoch() {
        let ds = synth::generate(100, 1);
        let mut b = Batcher::new(&ds, 10, 42);
        let mut seen = vec![0u32; 10];
        for _ in 0..10 {
            let (_, y) = b.next_batch();
            for l in y {
                seen[l as usize] += 1;
            }
        }
        assert_eq!(b.epochs, 0);
        // balanced dataset => exactly 10 of each class per epoch
        assert!(seen.iter().all(|&c| c == 10), "{seen:?}");
        b.next_batch();
        assert_eq!(b.epochs, 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = synth::generate(64, 2);
        let mut a = Batcher::new(&ds, 16, 7);
        let mut b = Batcher::new(&ds, 16, 7);
        for _ in 0..8 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    fn wraps_across_epoch() {
        let ds = synth::generate(10, 3);
        let mut b = Batcher::new(&ds, 4, 1);
        for _ in 0..5 {
            b.next_batch(); // 20 samples from a 10-sample set
        }
        assert_eq!(b.epochs, 1);
    }

    #[test]
    fn eval_covers_exactly_once() {
        let ds = synth::generate(25, 4);
        let mut e = EvalBatcher::new(&ds, 10);
        assert_eq!(e.num_batches(), 3);
        let mut x = vec![0.0; 10 * IMG_PIXELS];
        let mut y = vec![0; 10];
        let mut total = 0;
        let mut batches = 0;
        while let Some(v) = e.next_into(&mut x, &mut y) {
            total += v;
            batches += 1;
        }
        assert_eq!(total, 25);
        assert_eq!(batches, 3);
        assert!(e.next_into(&mut x, &mut y).is_none());
        e.reset();
        assert_eq!(e.next_into(&mut x, &mut y), Some(10));
    }
}
