//! Data pipeline: dataset container, MNIST IDX(+gz) loader, offline
//! synthetic-digit substitute, the shuffling batcher, and a process-wide
//! dataset cache ([`cache`]) so multi-run sweeps parse MNIST once.
//!
//! Resolution order (see [`load_default`]): real MNIST from `$MNIST_DIR`
//! (or `./data/mnist`) when the IDX files exist, otherwise the synthetic
//! generator (DESIGN.md substitution #2 — this environment is offline).

pub mod batcher;
pub mod cache;
pub mod mnist;
pub mod synth;

pub use batcher::{Batcher, EvalBatcher};

pub const IMG_SIDE: usize = 28;
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
pub const NUM_CLASSES: usize = 10;

/// An in-memory image-classification dataset (f32 pixels in `[0, 1]`).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major `n * IMG_PIXELS`.
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
    pub n: usize,
}

impl Dataset {
    pub fn new(images: Vec<f32>, labels: Vec<u8>) -> Self {
        assert_eq!(images.len(), labels.len() * IMG_PIXELS);
        let n = labels.len();
        Self { images, labels, n }
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }

    /// Contiguous sub-range `[lo, hi)` as an owned dataset — reference
    /// slices for piecewise-vs-whole eval-exactness checks.
    pub fn slice(&self, lo: usize, hi: usize) -> Dataset {
        assert!(lo <= hi && hi <= self.n, "slice {lo}..{hi} of {}", self.n);
        Dataset::new(
            self.images[lo * IMG_PIXELS..hi * IMG_PIXELS].to_vec(),
            self.labels[lo..hi].to_vec(),
        )
    }

    /// Class histogram (useful for sanity checks and tests).
    pub fn class_counts(&self) -> [usize; NUM_CLASSES] {
        let mut c = [0; NUM_CLASSES];
        for &l in &self.labels {
            c[l as usize] += 1;
        }
        c
    }

    /// Cheap content fingerprint: FNV-1a over the size, every label, and a
    /// strided sample of pixel bit patterns (≈1k probes regardless of set
    /// size).  Used by the engine's cached eval set to detect that a caller
    /// swapped datasets between `evaluate()` calls without paying a full
    /// O(pixels) hash per eval pass.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |b: u64| {
            h ^= b;
            h = h.wrapping_mul(PRIME);
        };
        mix(self.n as u64);
        for &l in &self.labels {
            mix(l as u64);
        }
        let stride = (self.images.len() / 1024).max(1);
        for i in (0..self.images.len()).step_by(stride) {
            mix(self.images[i].to_bits() as u64);
        }
        h
    }
}

/// Where a dataset came from (logged into experiment records).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Source {
    Mnist(String),
    Synthetic { seed: u64 },
}

/// Load train/test sets: real MNIST if present, synthetic otherwise.
///
/// "Present but unreadable" (corrupt/truncated/partial IDX files) is warned
/// about loudly before falling back — a run that silently trained on
/// synthetic digits when the user staged real MNIST would be misleading.
pub fn load_default(train_n: usize, test_n: usize) -> (Dataset, Dataset, Source) {
    let dir = std::env::var("MNIST_DIR").unwrap_or_else(|_| "data/mnist".into());
    match mnist::try_load_dir(&dir) {
        Ok(Some(pair)) => {
            crate::log_info!("data: using MNIST from {dir}");
            return (pair.0, pair.1, Source::Mnist(dir));
        }
        Ok(None) => crate::log_info!("data: MNIST not found at {dir}"),
        Err(e) => crate::log_warn!(
            "data: MNIST at {dir} is present but unreadable ({e:#}); \
             falling back to synthetic digits"
        ),
    }
    let seed = 2018;
    crate::log_info!(
        "data: generating synthetic digits (train={train_n}, test={test_n}, seed={seed})"
    );
    let train = synth::generate(train_n, seed);
    let test = synth::generate(test_n, seed + 1);
    (train, test, Source::Synthetic { seed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_accessors() {
        let ds = Dataset::new(vec![0.5; IMG_PIXELS * 3], vec![1, 2, 1]);
        assert_eq!(ds.n, 3);
        assert_eq!(ds.image(2).len(), IMG_PIXELS);
        let c = ds.class_counts();
        assert_eq!(c[1], 2);
        assert_eq!(c[2], 1);
    }

    #[test]
    #[should_panic]
    fn dataset_size_mismatch_panics() {
        Dataset::new(vec![0.0; 10], vec![1, 2]);
    }

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let a = synth::generate(25, 11);
        assert_eq!(a.fingerprint(), a.fingerprint(), "deterministic");
        assert_eq!(a.fingerprint(), a.clone().fingerprint(), "clone-invariant");

        let b = synth::generate(25, 12);
        assert_ne!(a.fingerprint(), b.fingerprint(), "different content");

        let mut label_flip = a.clone();
        label_flip.labels[3] = (label_flip.labels[3] + 1) % NUM_CLASSES as u8;
        assert_ne!(a.fingerprint(), label_flip.fingerprint(), "label change");

        let mut sized = a.clone();
        sized.images.truncate(24 * IMG_PIXELS);
        sized.labels.truncate(24);
        sized.n = 24;
        assert_ne!(a.fingerprint(), sized.fingerprint(), "size change");
    }
}
