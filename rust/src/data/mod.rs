//! Data pipeline: dataset container, MNIST IDX(+gz) loader, offline
//! synthetic-digit substitute, and the shuffling batcher.
//!
//! Resolution order (see [`load_default`]): real MNIST from `$MNIST_DIR`
//! (or `./data/mnist`) when the IDX files exist, otherwise the synthetic
//! generator (DESIGN.md substitution #2 — this environment is offline).

pub mod batcher;
pub mod mnist;
pub mod synth;

pub use batcher::{Batcher, EvalBatcher};

pub const IMG_SIDE: usize = 28;
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
pub const NUM_CLASSES: usize = 10;

/// An in-memory image-classification dataset (f32 pixels in `[0, 1]`).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major `n * IMG_PIXELS`.
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
    pub n: usize,
}

impl Dataset {
    pub fn new(images: Vec<f32>, labels: Vec<u8>) -> Self {
        assert_eq!(images.len(), labels.len() * IMG_PIXELS);
        let n = labels.len();
        Self { images, labels, n }
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }

    /// Contiguous sub-range `[lo, hi)` as an owned dataset — reference
    /// slices for piecewise-vs-whole eval-exactness checks.
    pub fn slice(&self, lo: usize, hi: usize) -> Dataset {
        assert!(lo <= hi && hi <= self.n, "slice {lo}..{hi} of {}", self.n);
        Dataset::new(
            self.images[lo * IMG_PIXELS..hi * IMG_PIXELS].to_vec(),
            self.labels[lo..hi].to_vec(),
        )
    }

    /// Class histogram (useful for sanity checks and tests).
    pub fn class_counts(&self) -> [usize; NUM_CLASSES] {
        let mut c = [0; NUM_CLASSES];
        for &l in &self.labels {
            c[l as usize] += 1;
        }
        c
    }
}

/// Where a dataset came from (logged into experiment records).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Source {
    Mnist(String),
    Synthetic { seed: u64 },
}

/// Load train/test sets: real MNIST if present, synthetic otherwise.
///
/// "Present but unreadable" (corrupt/truncated/partial IDX files) is warned
/// about loudly before falling back — a run that silently trained on
/// synthetic digits when the user staged real MNIST would be misleading.
pub fn load_default(train_n: usize, test_n: usize) -> (Dataset, Dataset, Source) {
    let dir = std::env::var("MNIST_DIR").unwrap_or_else(|_| "data/mnist".into());
    match mnist::try_load_dir(&dir) {
        Ok(Some(pair)) => {
            crate::log_info!("data: using MNIST from {dir}");
            return (pair.0, pair.1, Source::Mnist(dir));
        }
        Ok(None) => crate::log_info!("data: MNIST not found at {dir}"),
        Err(e) => crate::log_warn!(
            "data: MNIST at {dir} is present but unreadable ({e:#}); \
             falling back to synthetic digits"
        ),
    }
    let seed = 2018;
    crate::log_info!(
        "data: generating synthetic digits (train={train_n}, test={test_n}, seed={seed})"
    );
    let train = synth::generate(train_n, seed);
    let test = synth::generate(test_n, seed + 1);
    (train, test, Source::Synthetic { seed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_accessors() {
        let ds = Dataset::new(vec![0.5; IMG_PIXELS * 3], vec![1, 2, 1]);
        assert_eq!(ds.n, 3);
        assert_eq!(ds.image(2).len(), IMG_PIXELS);
        let c = ds.class_counts();
        assert_eq!(c[1], 2);
        assert_eq!(c[2], 1);
    }

    #[test]
    #[should_panic]
    fn dataset_size_mismatch_panics() {
        Dataset::new(vec![0.0; 10], vec![1, 2]);
    }
}
