//! Process-wide dataset cache: parse MNIST (or generate the synthetic
//! substitute) once per process, not once per run.
//!
//! Sweeps dispatch many runs through [`crate::coordinator::sharder`] —
//! serially, on `--jobs` worker threads, or as subprocess shards — and
//! every run used to re-read and re-gunzip the same IDX files.  This module
//! keys loaded `(train, test)` pairs by **resolved source + requested
//! sizes** and hands out `Arc<Dataset>` clones, so the parse cost is paid
//! exactly once per process and workers share one allocation.
//!
//! Hit/miss traffic is visible as the `data.cache_hits` /
//! `data.cache_misses` telemetry counters.  The cache sits *below* the
//! session's retry/fault-injection wrapper on purpose: `read-fail` specs
//! still fire on every run's load call, and only a successful load is
//! memoized.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::{Dataset, Source};

/// Resolved MNIST directory (the `$MNIST_DIR` fallback chain) plus the
/// requested `(train_n, test_n)` — everything [`super::load_default`]'s
/// result depends on.
type Key = (String, usize, usize);

/// What one cached load holds: shared train/test sets plus their source.
pub type CachedPair = (Arc<Dataset>, Arc<Dataset>, Source);

static CACHE: Mutex<BTreeMap<Key, CachedPair>> = Mutex::new(BTreeMap::new());

/// Cached [`super::load_default`]: identical resolution semantics, but the
/// parse happens at most once per process for a given source + size pair.
pub fn load_default_cached(train_n: usize, test_n: usize) -> CachedPair {
    let dir = std::env::var("MNIST_DIR").unwrap_or_else(|_| "data/mnist".into());
    fetch((dir, train_n, test_n), || {
        let (train, test, source) = super::load_default(train_n, test_n);
        (Arc::new(train), Arc::new(test), source)
    })
}

/// Look `key` up, loading (and memoizing) on a miss.  The lock is held
/// across the load on purpose: concurrent sweep workers asking for the
/// same key serialize into exactly one `data.cache_misses` plus `n - 1`
/// `data.cache_hits` — the deterministic totals the sharding tests pin.
fn fetch(key: Key, load: impl FnOnce() -> CachedPair) -> CachedPair {
    let mut map = CACHE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(hit) = map.get(&key) {
        crate::telemetry::count("data.cache_hits", 1);
        crate::log_debug!("data: cache hit ({}, train={}, test={})", key.0, key.1, key.2);
        return hit.clone();
    }
    crate::telemetry::count("data.cache_misses", 1);
    let entry = load();
    map.insert(key, entry.clone());
    entry
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn pair(n: usize, seed: u64) -> CachedPair {
        (
            Arc::new(synth::generate(n, seed)),
            Arc::new(synth::generate(n, seed + 1)),
            Source::Synthetic { seed },
        )
    }

    #[test]
    fn miss_then_hit_shares_one_load() {
        let key = ("test://miss_then_hit".to_string(), 3, 3);
        let miss0 = crate::telemetry::counter("data.cache_misses");
        let hit0 = crate::telemetry::counter("data.cache_hits");
        let mut loads = 0;
        let a = fetch(key.clone(), || {
            loads += 1;
            pair(3, 41)
        });
        let b = fetch(key, || {
            loads += 1;
            pair(3, 99)
        });
        assert_eq!(loads, 1, "the second fetch must not reload");
        assert!(Arc::ptr_eq(&a.0, &b.0), "hits share one allocation");
        assert!(Arc::ptr_eq(&a.1, &b.1));
        assert_eq!(a.2, b.2, "the source travels with the cached pair");
        assert_eq!(crate::telemetry::counter("data.cache_misses"), miss0 + 1);
        assert_eq!(crate::telemetry::counter("data.cache_hits"), hit0 + 1);
    }

    #[test]
    fn distinct_keys_load_independently() {
        let a = fetch(("test://distinct".into(), 1, 1), || pair(2, 7));
        let b = fetch(("test://distinct".into(), 2, 1), || pair(2, 8));
        assert!(!Arc::ptr_eq(&a.0, &b.0), "size is part of the key");
        assert_ne!(a.2, b.2);
    }

    #[test]
    fn load_default_cached_matches_uncached() {
        // the offline environment resolves to the deterministic synthetic
        // generator, so a cached load and a direct load must agree
        let (train, test, source) = load_default_cached(12, 6);
        let (train2, test2, source2) = crate::data::load_default(12, 6);
        assert_eq!(train.n, train2.n);
        assert_eq!(train.labels, train2.labels);
        assert_eq!(test.n, test2.n);
        assert_eq!(test.labels, test2.labels);
        assert_eq!(source, source2);
    }
}
