//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many generated cases with a seeded [`Pcg32`]; on
//! failure it reports the case index and re-runnable seed.  Includes naive
//! linear shrinking for numeric cases (halve toward zero) which is enough
//! for the invariants tested in this repo.

use crate::util::rng::Pcg32;

pub const DEFAULT_CASES: usize = 256;

/// Generator context handed to each case.
pub struct Gen {
    pub rng: Pcg32,
    pub case: usize,
}

impl Gen {
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.rng.below((hi - lo + 1) as u32) as i32
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() as f32 * scale).collect()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len() as u32) as usize]
    }
}

/// Run `prop` over `cases` generated cases; panics with reproduction info
/// on the first failure.  `prop` returns `Err(msg)` to fail a case.
pub fn check<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let mut g = Gen { rng: Pcg32::new(seed, case as u64), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 reproduce with: check(\"{name}\", {seed}, {}, ..) and case {case}",
                case + 1
            );
        }
    }
}

/// Convenience: assert two f32 slices are within `tol` elementwise.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} != {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("[{i}]: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", 1, 50, |g| {
            count += 1;
            let v = g.f32_in(0.0, 1.0);
            if (0.0..=1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed at case 3")]
    fn failing_property_reports_case() {
        check("boom", 1, 10, |g| {
            if g.case == 3 {
                Err("intentional".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 2, 100, |g| {
            let i = g.i32_in(-3, 7);
            if !(-3..=7).contains(&i) {
                return Err(format!("i32 {i}"));
            }
            let u = g.usize_in(1, 5);
            if !(1..=5).contains(&u) {
                return Err(format!("usize {u}"));
            }
            let c = *g.choice(&[10, 20, 30]);
            if ![10, 20, 30].contains(&c) {
                return Err(format!("choice {c}"));
            }
            Ok(())
        });
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.000001], 1e-5).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5).is_err());
    }
}
