//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Model: `repro <subcommand> [--flag value] [--switch] [positional...]`.
//! Flags may appear as `--key value` or `--key=value`.  Unknown flags are
//! an error (catches typos in experiment scripts).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Declares the accepted surface for parsing/validation + help text.
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    /// (flag, value-name, help) — flags that take a value.
    pub flags: &'static [(&'static str, &'static str, &'static str)],
    /// (switch, help) — boolean flags.
    pub switches: &'static [(&'static str, &'static str)],
}

impl Args {
    pub fn parse(spec: &Spec, argv: &[String]) -> Result<Args> {
        let mut args = Args {
            subcommand: None,
            flags: BTreeMap::new(),
            switches: Vec::new(),
            positional: Vec::new(),
        };
        let takes_value: Vec<&str> = spec.flags.iter().map(|(f, _, _)| *f).collect();
        let is_switch: Vec<&str> = spec.switches.iter().map(|(s, _)| *s).collect();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                if is_switch.contains(&key) {
                    if inline.is_some() {
                        bail!("switch --{key} takes no value");
                    }
                    args.switches.push(key.to_string());
                } else if takes_value.contains(&key) {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{key} needs a value"))?
                            .clone(),
                    };
                    args.flags.entry(key.to_string()).or_default().push(val);
                } else {
                    bail!("unknown flag --{key} for '{}'\n{}", spec.name, spec.usage());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All occurrences (for repeatable flags like `--set`).
    pub fn flag_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn flag_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.flag(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key}={s}: {e}")),
        }
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    /// Positional argument by index (`repro ckpt prune` → `pos(0) == "prune"`).
    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }
}

impl Spec {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nflags:\n", self.name, self.about);
        for (f, v, h) in self.flags {
            s.push_str(&format!("  --{f} <{v}>  {h}\n"));
        }
        for (f, h) in self.switches {
            s.push_str(&format!("  --{f}  {h}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: Spec = Spec {
        name: "test",
        about: "testing",
        flags: &[("iters", "N", "iterations"), ("set", "k=v", "override")],
        switches: &[("verbose", "more logs")],
    };

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_switches() {
        let a = Args::parse(&SPEC, &argv(&["--iters", "100", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.flag("iters"), Some("100"));
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.pos(0), Some("pos1"));
        assert_eq!(a.pos(1), None);
        assert_eq!(a.flag_parse::<u64>("iters").unwrap(), Some(100));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&SPEC, &argv(&["--iters=42"])).unwrap();
        assert_eq!(a.flag("iters"), Some("42"));
    }

    #[test]
    fn repeatable() {
        let a = Args::parse(&SPEC, &argv(&["--set", "a=1", "--set", "b=2"])).unwrap();
        assert_eq!(a.flag_all("set"), &["a=1".to_string(), "b=2".to_string()]);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(Args::parse(&SPEC, &argv(&["--nope"])).is_err());
        assert!(Args::parse(&SPEC, &argv(&["--iters"])).is_err());
        assert!(Args::parse(&SPEC, &argv(&["--verbose=1"])).is_err());
        assert!(Args::parse(&SPEC, &argv(&["--iters", "abc"]))
            .unwrap()
            .flag_parse::<u64>("iters")
            .is_err());
    }
}
