//! Log2-bucketed latency histogram: fixed memory, commutative merge.
//!
//! Durations (nanoseconds) land in bucket `floor(log2(ns))`, so 64 buckets
//! cover the full `u64` range with ~2x resolution — plenty for "which phase
//! got slower" questions, and cheap enough to record on every span with no
//! sink attached.  Exact `min`/`max` ride alongside the buckets, and
//! percentiles are answered from the bucket boundaries (upper bound of the
//! bucket holding the requested rank, clamped to the exact extremes).
//!
//! Merging adds bucket counts element-wise; addition is commutative and
//! associative, so per-worker histograms merged in any order produce the
//! same totals — the property [`crate::coordinator::sharder`] relies on for
//! deterministic sweep telemetry.

use crate::util::json::Json;

const BUCKETS: usize = 64;

/// One phase's duration distribution (all values in nanoseconds).
#[derive(Debug, Clone)]
pub struct Hist {
    count: u64,
    sum_ns: u64,
    min_ns: u64,
    max_ns: u64,
    buckets: [u64; BUCKETS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist { count: 0, sum_ns: 0, min_ns: u64::MAX, max_ns: 0, buckets: [0; BUCKETS] }
    }
}

/// Bucket index for a duration: `floor(log2(ns))`, with 0 ns in bucket 0.
fn bucket_of(ns: u64) -> usize {
    (63 - ns.max(1).leading_zeros()) as usize
}

/// Inclusive upper bound of a bucket (`2^(i+1) - 1`).
fn bucket_hi(i: usize) -> u64 {
    if i + 1 >= 64 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    pub fn record(&mut self, ns: u64) {
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
        self.buckets[bucket_of(ns)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Value at quantile `q` in `[0, 1]`: the upper bound of the bucket
    /// holding the `ceil(q * count)`-th sample, clamped into the exact
    /// `[min, max]` envelope (so p100 is exact and p0 never undershoots).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_hi(i).clamp(self.min_ns, self.max_ns);
            }
        }
        self.max_ns
    }

    /// Element-wise bucket addition (commutative — see module docs).
    pub fn merge(&mut self, other: &Hist) {
        if other.count == 0 {
            return;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    /// Counts in `other` but not yet in `self` removed — the per-run delta
    /// of a thread-accumulated histogram (`self` is the later snapshot).
    pub fn diff(&self, earlier: &Hist) -> Hist {
        let mut out = Hist {
            count: self.count.saturating_sub(earlier.count),
            sum_ns: self.sum_ns.saturating_sub(earlier.sum_ns),
            // extremes are not subtractable; keep the later snapshot's view
            min_ns: self.min_ns,
            max_ns: self.max_ns,
            buckets: [0; BUCKETS],
        };
        for i in 0..BUCKETS {
            out.buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        out
    }

    /// JSON form: summary stats plus the sparse `[bucket, count]` pairs
    /// needed to reconstruct the distribution.
    pub fn to_json(&self) -> Json {
        let sparse: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| Json::arr_f64(&[i as f64, n as f64]))
            .collect();
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum_ns", Json::Num(self.sum_ns as f64)),
            ("min_ns", Json::Num(self.min_ns() as f64)),
            ("max_ns", Json::Num(self.max_ns as f64)),
            ("p50_ns", Json::Num(self.quantile_ns(0.50) as f64)),
            ("p95_ns", Json::Num(self.quantile_ns(0.95) as f64)),
            ("p99_ns", Json::Num(self.quantile_ns(0.99) as f64)),
            ("buckets", Json::Arr(sparse)),
        ])
    }

    /// Rebuild from [`Self::to_json`] output (derived percentiles are
    /// recomputed, not trusted).
    pub fn from_json(j: &Json) -> anyhow::Result<Hist> {
        use anyhow::Context;
        let mut h = Hist {
            count: j.get("count").as_f64().context("hist 'count'")? as u64,
            sum_ns: j.get("sum_ns").as_f64().context("hist 'sum_ns'")? as u64,
            min_ns: j.get("min_ns").as_f64().context("hist 'min_ns'")? as u64,
            max_ns: j.get("max_ns").as_f64().context("hist 'max_ns'")? as u64,
            buckets: [0; BUCKETS],
        };
        if h.count == 0 {
            h.min_ns = u64::MAX;
        }
        for pair in j.get("buckets").as_arr().context("hist 'buckets'")? {
            let i = pair.at(0).as_usize().context("bucket index")?;
            let n = pair.at(1).as_f64().context("bucket count")? as u64;
            anyhow::ensure!(i < BUCKETS, "bucket index {i} out of range");
            h.buckets[i] = n;
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), 63);
    }

    #[test]
    fn record_and_quantiles() {
        let mut h = Hist::new();
        for ns in [100u64, 200, 300, 400, 10_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min_ns(), 100);
        assert_eq!(h.max_ns(), 10_000);
        assert_eq!(h.sum_ns(), 11_000);
        // p50 lands in the bucket of 200/300 (128..255 or 256..511)
        let p50 = h.quantile_ns(0.5);
        assert!((100..=511).contains(&p50), "p50={p50}");
        assert_eq!(h.quantile_ns(1.0), 10_000, "p100 is the exact max");
        assert!(h.quantile_ns(0.0) >= 100);
    }

    #[test]
    fn empty_hist_is_quiet() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.99), 0);
    }

    #[test]
    fn merge_is_commutative() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        for ns in [10u64, 1000, 50_000] {
            a.record(ns);
        }
        for ns in [7u64, 7, 2_000_000] {
            b.record(ns);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.count(), 6);
        assert_eq!(ab.count(), ba.count());
        assert_eq!(ab.sum_ns(), ba.sum_ns());
        assert_eq!(ab.min_ns(), 7);
        assert_eq!(ab.max_ns(), 2_000_000);
        assert_eq!(ab.quantile_ns(0.5), ba.quantile_ns(0.5));
    }

    #[test]
    fn diff_removes_earlier_counts() {
        let mut earlier = Hist::new();
        earlier.record(100);
        let mut later = earlier.clone();
        later.record(100);
        later.record(3000);
        let d = later.diff(&earlier);
        assert_eq!(d.count(), 2);
        assert_eq!(d.sum_ns(), 3100);
    }

    #[test]
    fn json_roundtrip() {
        let mut h = Hist::new();
        for ns in [5u64, 80, 80, 12_345, 999_999_999] {
            h.record(ns);
        }
        let j = h.to_json();
        let back = Hist::from_json(&j).unwrap();
        assert_eq!(back.count(), h.count());
        assert_eq!(back.sum_ns(), h.sum_ns());
        assert_eq!(back.min_ns(), h.min_ns());
        assert_eq!(back.max_ns(), h.max_ns());
        assert_eq!(back.quantile_ns(0.95), h.quantile_ns(0.95));
        // empty hist round-trips too
        let e = Hist::from_json(&Hist::new().to_json()).unwrap();
        assert_eq!(e.count(), 0);
        assert_eq!(e.min_ns(), 0);
    }
}
