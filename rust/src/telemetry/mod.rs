//! Lightweight, zero-dependency instrumentation: spans, counters, and an
//! optional JSONL trace sink.
//!
//! The training stack runs single-threaded per run ([`crate::runtime::Runtime`]
//! is not `Send`), so telemetry follows the same shape as the old
//! `literal_builds`/`host_transfers` counters it absorbs: every thread owns a
//! private [`Registry`] (a thread-local; no locks or atomics on the hot path)
//! and cross-thread aggregation is explicit — a sweep worker finishes,
//! captures a [`Snapshot`], and the coordinator [`absorb`]s the snapshots in
//! worker-index order.  Counter addition and histogram bucket addition are
//! commutative, so serial, threaded, and sharded sweeps report identical
//! merged totals (pinned by `tests/sharding_equivalence.rs`).
//!
//! Three primitives:
//!
//! - **Counters** — monotonic event counts (`telemetry::count("watchdog.trips",
//!   1)`); [`gauge`] overwrites instead of adding for level-style values.
//!   The counter catalog lives in ROADMAP.md's observability section.
//! - **Spans** — RAII timers: `let _s = telemetry::span!("engine.step");`
//!   records the scope's wall duration into a per-name log2 histogram
//!   ([`Hist`]) on drop.  With no trace sink attached the cost is two
//!   `Instant` reads plus a thread-local map bump.
//! - **Trace sink** — [`TraceGuard::attach`] (CLI `--trace <path>` / config
//!   `telemetry.trace_path`) streams every span end and counter bump as one
//!   JSON object per line, stamped with the current training iteration
//!   ([`set_iter`]) and the wall offset since attach.  `repro trace
//!   summarize <file>` ([`trace`]) renders the per-phase timing table.
//!
//! Per-run [`Snapshot`] deltas land in
//! [`crate::metrics::History::summary_json`] under `"telemetry"`, so every
//! recorded experiment carries its own counter/phase audit trail.

pub mod hist;
pub mod trace;

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::io::Write;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

pub use hist::Hist;

/// One thread's counters and span histograms.
#[derive(Debug, Clone, Default)]
struct Registry {
    counters: BTreeMap<String, u64>,
    spans: BTreeMap<String, Hist>,
}

thread_local! {
    static REGISTRY: RefCell<Registry> = RefCell::new(Registry::default());
    /// Training iteration stamped onto trace events (see [`set_iter`]).
    static ITER: Cell<u64> = const { Cell::new(0) };
    static SINK: RefCell<Option<TraceSink>> = const { RefCell::new(None) };
}

// --------------------------------------------------------------- counters

/// Add `n` to counter `name` (creating it at zero first).  Names are
/// dot-separated static identifiers (`"runtime.host_transfers"`); keep them
/// free of quotes/backslashes — the trace sink writes them unescaped.
pub fn count(name: &str, n: u64) {
    if n == 0 {
        return;
    }
    let total = REGISTRY.with(|r| {
        let mut reg = r.borrow_mut();
        let slot = reg.counters.entry(name.to_string()).or_insert(0);
        *slot += n;
        *slot
    });
    trace_count(name, n, total);
}

/// Overwrite counter `name` with an absolute level (gauge semantics).
pub fn gauge(name: &str, value: u64) {
    REGISTRY.with(|r| {
        r.borrow_mut().counters.insert(name.to_string(), value);
    });
    trace_count(name, 0, value);
}

/// Current value of counter `name` on this thread (0 if never bumped).
pub fn counter(name: &str) -> u64 {
    REGISTRY.with(|r| r.borrow().counters.get(name).copied().unwrap_or(0))
}

/// Stamp the training iteration onto subsequent trace events.
pub fn set_iter(iter: u64) {
    ITER.with(|i| i.set(iter));
}

// ------------------------------------------------------------------ spans

/// RAII span: created by [`start_span`] / `telemetry::span!`, records its
/// wall duration into the per-name histogram when dropped.
pub struct Span {
    name: &'static str,
    start: Instant,
}

/// Start timing a named phase.  Bind the result (`let _s = ...`) — an
/// unnamed `_` drops immediately and times nothing.
pub fn start_span(name: &'static str) -> Span {
    Span { name, start: Instant::now() }
}

impl Drop for Span {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        REGISTRY.with(|r| {
            r.borrow_mut().spans.entry(self.name.to_string()).or_default().record(ns)
        });
        trace_span(self.name, ns);
    }
}

/// `telemetry::span!("engine.step")` — see [`start_span`].
#[macro_export]
macro_rules! telemetry_span {
    ($name:expr) => {
        $crate::telemetry::start_span($name)
    };
}
pub use crate::telemetry_span as span;

// -------------------------------------------------------------- snapshots

/// A point-in-time copy of one registry: `Send + Clone`, mergeable, and
/// JSON round-trippable.  Captured per worker by the sweep coordinator and
/// per run by [`crate::trainer::Session`].
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    counters: BTreeMap<String, u64>,
    spans: BTreeMap<String, Hist>,
}

impl Snapshot {
    pub fn counters(&self) -> &BTreeMap<String, u64> {
        &self.counters
    }

    pub fn spans(&self) -> &BTreeMap<String, Hist> {
        &self.spans
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.spans.is_empty()
    }

    /// What happened since `earlier` (counter-wise and bucket-wise
    /// subtraction; zero entries are dropped so deltas stay sparse).
    pub fn diff(&self, earlier: &Snapshot) -> Snapshot {
        let mut out = Snapshot::default();
        for (k, &v) in &self.counters {
            let d = v.saturating_sub(earlier.counter(k));
            if d > 0 {
                out.counters.insert(k.clone(), d);
            }
        }
        for (k, h) in &self.spans {
            let d = match earlier.spans.get(k) {
                Some(e) => h.diff(e),
                None => h.clone(),
            };
            if d.count() > 0 {
                out.spans.insert(k.clone(), d);
            }
        }
        out
    }

    /// Fold `other` into `self` (commutative totals — see module docs).
    pub fn merge(&mut self, other: &Snapshot) {
        for (k, &v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.spans {
            self.spans.entry(k.clone()).or_default().merge(h);
        }
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                .collect(),
        );
        let spans = Json::Obj(
            self.spans
                .iter()
                .map(|(k, h)| (k.clone(), h.to_json()))
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("spans", spans)])
    }

    pub fn from_json(j: &Json) -> Result<Snapshot> {
        let mut out = Snapshot::default();
        if let Some(m) = j.get("counters").as_obj() {
            for (k, v) in m {
                out.counters
                    .insert(k.clone(), v.as_f64().context("counter value")? as u64);
            }
        }
        if let Some(m) = j.get("spans").as_obj() {
            for (k, v) in m {
                out.spans.insert(
                    k.clone(),
                    Hist::from_json(v).with_context(|| format!("span '{k}'"))?,
                );
            }
        }
        Ok(out)
    }
}

/// Copy this thread's registry (counters + span histograms).
pub fn snapshot() -> Snapshot {
    REGISTRY.with(|r| {
        let reg = r.borrow();
        Snapshot { counters: reg.counters.clone(), spans: reg.spans.clone() }
    })
}

/// Merge a snapshot into this thread's registry — how the sweep coordinator
/// adopts its workers' telemetry (call in worker-index order; totals are
/// order-independent anyway).
pub fn absorb(snap: &Snapshot) {
    REGISTRY.with(|r| {
        let mut reg = r.borrow_mut();
        for (k, &v) in &snap.counters {
            *reg.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &snap.spans {
            reg.spans.entry(k.clone()).or_default().merge(h);
        }
    });
}

// ------------------------------------------------------------- trace sink

struct TraceSink {
    w: std::io::BufWriter<std::fs::File>,
    start: Instant,
}

/// Open a JSONL trace sink on this thread; subsequent span/counter events
/// stream to it until [`detach_trace`].  Replaces any sink already attached.
pub fn attach_trace(path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let f = std::fs::File::create(path).with_context(|| format!("opening trace {path}"))?;
    SINK.with(|s| {
        *s.borrow_mut() = Some(TraceSink { w: std::io::BufWriter::new(f), start: Instant::now() })
    });
    Ok(())
}

/// Flush and close this thread's trace sink (no-op when none is attached).
pub fn detach_trace() {
    SINK.with(|s| {
        if let Some(mut sink) = s.borrow_mut().take() {
            let _ = sink.w.flush();
        }
    });
}

/// Is a trace sink attached on this thread?
pub fn trace_active() -> bool {
    SINK.with(|s| s.borrow().is_some())
}

fn trace_span(name: &str, ns: u64) {
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            let t = sink.start.elapsed().as_secs_f64();
            let iter = ITER.with(|i| i.get());
            let _ = writeln!(
                sink.w,
                r#"{{"t":{t:.6},"kind":"span","name":"{name}","iter":{iter},"dur_us":{:.3}}}"#,
                ns as f64 / 1e3
            );
        }
    });
}

fn trace_count(name: &str, n: u64, total: u64) {
    SINK.with(|s| {
        if let Some(sink) = s.borrow_mut().as_mut() {
            let t = sink.start.elapsed().as_secs_f64();
            let iter = ITER.with(|i| i.get());
            let _ = writeln!(
                sink.w,
                r#"{{"t":{t:.6},"kind":"count","name":"{name}","iter":{iter},"n":{n},"total":{total}}}"#
            );
        }
    });
}

/// RAII wrapper for an optional trace sink: attaches on construction (a
/// failed open warns and traces nothing — observability must never kill a
/// run), detaches and flushes on drop.
pub struct TraceGuard {
    active: bool,
}

impl TraceGuard {
    pub fn attach(path: Option<&str>) -> TraceGuard {
        match path {
            Some(p) => match attach_trace(p) {
                Ok(()) => {
                    crate::log_info!("telemetry: tracing to {p}");
                    TraceGuard { active: true }
                }
                Err(e) => {
                    crate::log_warn!("telemetry: trace disabled ({e:#})");
                    TraceGuard { active: false }
                }
            },
            None => TraceGuard { active: false },
        }
    }

    pub fn active(&self) -> bool {
        self.active
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        if self.active {
            detach_trace();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_per_thread() {
        let before = counter("test.alpha");
        count("test.alpha", 2);
        count("test.alpha", 3);
        count("test.alpha", 0); // no-op, not even an entry
        assert_eq!(counter("test.alpha"), before + 5);
        gauge("test.level", 42);
        gauge("test.level", 7);
        assert_eq!(counter("test.level"), 7, "gauge overwrites");
    }

    #[test]
    fn spans_feed_histograms() {
        let before = snapshot().spans().get("test.span").map(|h| h.count()).unwrap_or(0);
        {
            let _s = span!("test.span");
            std::hint::black_box(0u64);
        }
        {
            let _s = start_span("test.span");
        }
        let snap = snapshot();
        let h = snap.spans().get("test.span").expect("span recorded");
        assert_eq!(h.count(), before + 2);
        assert!(h.max_ns() > 0 || h.count() > 0);
    }

    #[test]
    fn snapshot_diff_and_merge() {
        count("test.diff", 10);
        let a = snapshot();
        count("test.diff", 4);
        {
            let _s = span!("test.diff_span");
        }
        let b = snapshot();
        let d = b.diff(&a);
        assert_eq!(d.counter("test.diff"), 4);
        assert_eq!(d.spans().get("test.diff_span").map(|h| h.count()), Some(1));
        assert_eq!(d.counter("test.never"), 0);

        let mut merged = d.clone();
        merged.merge(&d);
        assert_eq!(merged.counter("test.diff"), 8);
        assert_eq!(merged.spans()["test.diff_span"].count(), 2);
    }

    #[test]
    fn absorb_is_order_independent() {
        let mut a = Snapshot::default();
        a.counters.insert("x".into(), 3);
        let mut b = Snapshot::default();
        b.counters.insert("x".into(), 5);
        b.counters.insert("y".into(), 1);

        let base = snapshot();
        absorb(&a);
        absorb(&b);
        let ab = snapshot().diff(&base);
        assert_eq!(ab.counter("x"), 8);
        assert_eq!(ab.counter("y"), 1);
    }

    #[test]
    fn snapshot_json_roundtrip() {
        let mut s = Snapshot::default();
        s.counters.insert("runtime.host_transfers".into(), 12);
        s.counters.insert("watchdog.trips".into(), 2);
        let mut h = Hist::new();
        for ns in [1_000u64, 2_000, 3_000_000] {
            h.record(ns);
        }
        s.spans.insert("engine.step".into(), h);

        let text = s.to_json().to_string_pretty();
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.counter("runtime.host_transfers"), 12);
        assert_eq!(back.counter("watchdog.trips"), 2);
        let hb = &back.spans()["engine.step"];
        assert_eq!(hb.count(), 3);
        assert_eq!(hb.min_ns(), 1_000);
        assert_eq!(hb.max_ns(), 3_000_000);
    }

    #[test]
    fn trace_sink_streams_jsonl() {
        let dir = std::env::temp_dir().join("qedps_telemetry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let pathstr = path.to_string_lossy().into_owned();
        {
            let guard = TraceGuard::attach(Some(&pathstr));
            assert!(guard.active());
            assert!(trace_active());
            set_iter(7);
            count("test.trace_counter", 3);
            {
                let _s = span!("test.trace_span");
            }
        }
        assert!(!trace_active(), "guard detaches on drop");
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let j = Json::parse(line).expect("each line is standalone JSON");
            assert_eq!(j.get("iter").as_f64(), Some(7.0));
        }
        let j0 = Json::parse(lines[0]).unwrap();
        assert_eq!(j0.get("kind").as_str(), Some("count"));
        assert_eq!(j0.get("name").as_str(), Some("test.trace_counter"));
        assert_eq!(j0.get("n").as_f64(), Some(3.0));
        let j1 = Json::parse(lines[1]).unwrap();
        assert_eq!(j1.get("kind").as_str(), Some("span"));
        assert!(j1.get("dur_us").as_f64().is_some());
    }

    #[test]
    fn missing_trace_dir_is_nonfatal() {
        let guard = TraceGuard::attach(Some("/dev/null/nope/trace.jsonl"));
        assert!(!guard.active(), "unwritable path disables tracing");
        let none = TraceGuard::attach(None);
        assert!(!none.active());
    }
}
