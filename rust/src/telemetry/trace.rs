//! Offline analysis of JSONL trace files (`repro trace summarize <file>`).
//!
//! The trace sink ([`super::TraceGuard`]) writes one JSON object per line;
//! this module reads a file back, folds span events into exact per-phase
//! duration stats (the raw durations are kept, so percentiles here are
//! exact rather than log2-bucketed) and counter events into (delta, final
//! total) pairs, and renders the result as an aligned text table.
//! Malformed lines are counted and skipped — a trace truncated by a crash
//! must still summarize, that is half the point of tracing.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Exact duration stats for one span name.
#[derive(Debug, Clone, Default)]
pub struct SpanStats {
    /// Microsecond durations in arrival order.
    durs_us: Vec<f64>,
    first_iter: u64,
    last_iter: u64,
}

impl SpanStats {
    pub fn count(&self) -> usize {
        self.durs_us.len()
    }

    pub fn sum_us(&self) -> f64 {
        self.durs_us.iter().sum()
    }

    pub fn mean_us(&self) -> f64 {
        if self.durs_us.is_empty() {
            0.0
        } else {
            self.sum_us() / self.durs_us.len() as f64
        }
    }

    pub fn min_us(&self) -> f64 {
        if self.durs_us.is_empty() {
            0.0
        } else {
            self.durs_us.iter().copied().fold(f64::INFINITY, f64::min)
        }
    }

    pub fn max_us(&self) -> f64 {
        self.durs_us.iter().copied().fold(0.0, f64::max)
    }

    /// Exact quantile by nearest-rank on the sorted durations.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.durs_us.is_empty() {
            return 0.0;
        }
        let mut sorted = self.durs_us.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).max(1);
        sorted[rank - 1]
    }

    pub fn iter_range(&self) -> (u64, u64) {
        (self.first_iter, self.last_iter)
    }
}

/// Delta and final running total for one counter name.
#[derive(Debug, Clone, Copy, Default)]
pub struct CounterStats {
    pub delta: u64,
    pub last_total: u64,
}

/// Aggregated view of one trace file.
#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    pub spans: BTreeMap<String, SpanStats>,
    pub counters: BTreeMap<String, CounterStats>,
    /// Well-formed events consumed.
    pub events: usize,
    /// Lines that failed to parse or lacked required fields.
    pub skipped: usize,
    /// Wall offset of the last event (seconds since the sink attached).
    pub wall_s: f64,
}

impl TraceSummary {
    /// Fold one already-parsed trace event into the summary.  Returns
    /// `false` (and leaves the summary untouched except `skipped`) when the
    /// event is missing required fields.
    fn absorb_event(&mut self, j: &Json) -> bool {
        let (Some(kind), Some(name)) = (j.get("kind").as_str(), j.get("name").as_str()) else {
            return false;
        };
        let iter = j.get("iter").as_f64().unwrap_or(0.0) as u64;
        match kind {
            "span" => {
                let Some(dur) = j.get("dur_us").as_f64() else {
                    return false;
                };
                let s = self.spans.entry(name.to_string()).or_default();
                if s.durs_us.is_empty() {
                    s.first_iter = iter;
                }
                s.last_iter = iter;
                s.durs_us.push(dur);
            }
            "count" => {
                let Some(total) = j.get("total").as_f64() else {
                    return false;
                };
                let n = j.get("n").as_f64().unwrap_or(0.0) as u64;
                let c = self.counters.entry(name.to_string()).or_default();
                c.delta += n;
                c.last_total = total as u64;
            }
            _ => return false,
        }
        if let Some(t) = j.get("t").as_f64() {
            self.wall_s = self.wall_s.max(t);
        }
        self.events += 1;
        true
    }

    /// Parse a full JSONL trace body (already read into memory).
    pub fn from_jsonl(text: &str) -> TraceSummary {
        let mut out = TraceSummary::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match Json::parse(line) {
                Ok(j) => {
                    if !out.absorb_event(&j) {
                        out.skipped += 1;
                    }
                }
                Err(_) => out.skipped += 1,
            }
        }
        out
    }

    /// Human-readable report: per-phase timing table + counter deltas.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace: {} events over {:.3}s wall ({} malformed line{} skipped)\n",
            self.events,
            self.wall_s,
            self.skipped,
            if self.skipped == 1 { "" } else { "s" }
        ));

        out.push_str("\nspans (us):\n");
        if self.spans.is_empty() {
            out.push_str("  (none)\n");
        } else {
            out.push_str(&format!(
                "  {:<22} {:>8} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12}\n",
                "phase", "count", "total_ms", "mean", "min", "p50", "p95", "max"
            ));
            out.push_str(&format!("  {}\n", "-".repeat(108)));
            for (name, s) in &self.spans {
                out.push_str(&format!(
                    "  {:<22} {:>8} {:>12.3} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1}\n",
                    name,
                    s.count(),
                    s.sum_us() / 1e3,
                    s.mean_us(),
                    s.min_us(),
                    s.quantile_us(0.50),
                    s.quantile_us(0.95),
                    s.max_us(),
                ));
            }
        }

        out.push_str("\ncounters:\n");
        if self.counters.is_empty() {
            out.push_str("  (none)\n");
        } else {
            out.push_str(&format!(
                "  {:<28} {:>12} {:>12}\n",
                "counter", "delta", "final"
            ));
            out.push_str(&format!("  {}\n", "-".repeat(54)));
            for (name, c) in &self.counters {
                out.push_str(&format!(
                    "  {:<28} {:>12} {:>12}\n",
                    name, c.delta, c.last_total
                ));
            }
        }
        out
    }
}

/// Read and summarize a trace file written by the JSONL sink.
pub fn summarize(path: &str) -> Result<TraceSummary> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    Ok(TraceSummary::from_jsonl(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
{"t":0.000100,"kind":"count","name":"runtime.host_transfers","iter":0,"n":4,"total":4}
{"t":0.000200,"kind":"span","name":"engine.step","iter":0,"dur_us":120.500}
{"t":0.000300,"kind":"span","name":"engine.step","iter":1,"dur_us":80.000}
{"t":0.000400,"kind":"span","name":"engine.step","iter":2,"dur_us":100.000}
{"t":0.000500,"kind":"span","name":"session.eval","iter":2,"dur_us":900.000}
{"t":0.000600,"kind":"count","name":"runtime.host_transfers","iter":2,"n":2,"total":6}
{"t":0.000700,"kind":"count","name":"eval.batches","iter":2,"n":5,"total":5}
this line is not json
{"t":0.000800,"kind":"mystery","name":"x"}
"#;

    #[test]
    fn summarize_folds_spans_and_counters() {
        let s = TraceSummary::from_jsonl(SAMPLE);
        assert_eq!(s.events, 7);
        assert_eq!(s.skipped, 2, "garbage line + unknown kind");
        assert!((s.wall_s - 0.0007).abs() < 1e-9);

        let step = &s.spans["engine.step"];
        assert_eq!(step.count(), 3);
        assert!((step.sum_us() - 300.5).abs() < 1e-9);
        assert_eq!(step.iter_range(), (0, 2));
        assert!((step.min_us() - 80.0).abs() < 1e-9);
        assert!((step.max_us() - 120.5).abs() < 1e-9);
        assert!((step.quantile_us(0.5) - 100.0).abs() < 1e-9, "exact median");
        assert!((step.quantile_us(1.0) - 120.5).abs() < 1e-9);

        let ht = &s.counters["runtime.host_transfers"];
        assert_eq!(ht.delta, 6);
        assert_eq!(ht.last_total, 6);
        assert_eq!(s.counters["eval.batches"].delta, 5);
    }

    #[test]
    fn render_names_every_phase_and_counter() {
        let s = TraceSummary::from_jsonl(SAMPLE);
        let text = s.render();
        for needle in
            ["engine.step", "session.eval", "runtime.host_transfers", "eval.batches", "p95"]
        {
            assert!(text.contains(needle), "report missing '{needle}':\n{text}");
        }
    }

    #[test]
    fn empty_trace_summarizes_quietly() {
        let s = TraceSummary::from_jsonl("");
        assert_eq!(s.events, 0);
        assert_eq!(s.skipped, 0);
        let text = s.render();
        assert!(text.contains("(none)"));
    }

    #[test]
    fn summarize_reads_from_disk() {
        let dir = std::env::temp_dir().join("qedps_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        std::fs::write(&path, SAMPLE).unwrap();
        let s = summarize(&path.to_string_lossy()).unwrap();
        assert_eq!(s.events, 7);
        assert!(summarize("/nonexistent/trace.jsonl").is_err());
    }
}
