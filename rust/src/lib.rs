//! # qedps — quantization-error-driven dynamic precision scaling
//!
//! Rust coordinator (L3) of the three-layer reproduction of Stuart & Taras,
//! *"Quantization Error as a Metric for Dynamic Precision Scaling in Neural
//! Net Training"* (2018).  The compute graphs (L2: JAX, L1: Pallas) are
//! AOT-lowered to HLO text by `python/compile/aot.py`; this crate loads the
//! artifacts through the PJRT C API and owns everything at run time:
//!
//! * [`runtime`] — manifest-driven loading/execution of the AOT artifacts,
//!   including the device-buffer layer ([`runtime::device`]): parameters
//!   and momenta stay resident on the device between steps (the train
//!   modules are lowered with input→output donation), and
//!   [`runtime::host_transfers()`] counts every state-tensor copy across
//!   the host↔device boundary so perf tests can assert the steady state
//!   performs none;
//! * [`policy`] — the paper's contribution: the `<IL, FL>` controllers
//!   (quantization-error + overflow driven scaling, plus every baseline the
//!   paper compares against);
//! * [`trainer`] — the training loop, split into three layers:
//!   [`trainer::StepEngine`] (compiled executables + device-resident
//!   parameter state + pre-pinned input literals; the zero-allocation,
//!   zero-state-transfer step hot path, with a host-literal fallback, and
//!   exact per-example eval accumulation via [`trainer::EvalAccum`] so
//!   non-multiple test sets score bit-identically to a batch-size-1
//!   sweep; the test set itself is batched once into a cached eval set
//!   whose inputs go resident on first use, making steady-state eval
//!   passes prep- and upload-free — `repro bench eval` asserts it),
//!   [`trainer::Session`] (experiment lifecycle: data, watchdog,
//!   rollback, checkpoints), and the thin [`trainer::Trainer`] facade
//!   (policy + history around the engine);
//! * [`fixedpoint`] — bit-exact software mirror of the L1 quantizer (used
//!   by parity tests, the MAC simulator and the policy unit tests);
//! * [`data`] — MNIST IDX loader (streaming gzip decode) + the offline
//!   synthetic-digit substitute, behind a process-wide dataset cache
//!   ([`data::cache`]) so multi-run sweeps parse the data once per
//!   process and share one `Arc<Dataset>` allocation;
//! * [`macsim`] — cycle model of Na & Mukhopadhyay's flexible MAC unit
//!   (turns measured bit-width trajectories into hardware speedup);
//! * [`coordinator`] — experiment drivers that regenerate every figure and
//!   table in the paper; multi-run sweeps dispatch through
//!   [`coordinator::sharder`] (`--jobs` worker threads, `--shard i/n`
//!   subprocess slices) with deterministic, byte-identical merges;
//! * [`resilience`] — divergence watchdog, fault injection, retry/backoff
//!   and failure reporting (the run-survival layer around [`trainer`]);
//! * [`telemetry`] — zero-dep instrumentation: RAII spans feeding
//!   log2-bucketed histograms, a counter/gauge registry (the home of
//!   `literal_builds`/`host_transfers` and the resilience counters), an
//!   optional JSONL trace sink (`--trace` / `telemetry.trace_path`), and
//!   the `repro trace summarize` analyzer; per-worker registries merge
//!   deterministically across sweep dispatch modes;
//! * [`util`], [`config`], [`cli`], [`metrics`], [`bench`], [`testutil`] —
//!   in-repo substrates (JSON, TOML-subset config, CLI, CSV, RNG,
//!   micro-bench and property-test harnesses); the offline crate set has no
//!   serde/clap/criterion/proptest/rand.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation, and the `repro` binary is self-contained afterwards.
//!
//! ## Fault tolerance
//!
//! Training at the edge of divergence is the paper's operating point, so
//! the driver assumes runs *will* occasionally fall off it:
//!
//! * **Crash-safe checkpoints** — [`trainer::checkpoint`] stages each
//!   checkpoint in a temp dir, fsyncs, renames atomically, and stores an
//!   FNV-1a checksum in `state.json`; resume scans for the newest
//!   checkpoint that validates, so a torn or corrupt write is skipped, not
//!   fatal.
//! * **Divergence watchdog** — [`resilience::Watchdog`] trips on
//!   non-finite loss, loss explosion vs a running baseline, or a sustained
//!   overflow rate; the driver then rolls back to the last good
//!   checkpoint, widens precision via [`policy::Policy::escalate`], and
//!   replays deterministically, with a bounded retry budget and
//!   exponential post-rollback grace.  Static baselines opt out
//!   ([`policy::Policy::can_escalate`]): their divergence is the §5
//!   experiment.
//! * **Fault injection** — `--fault nan@N | inf@N | bitflip@N[:class] |
//!   read-fail[:N]` ([`resilience::FaultInjector`]) exercises all of the
//!   above deterministically; see `examples/fault_recovery.rs`.
//! * **Structured failure reports** — exhausting the retry budget writes
//!   `failure_report.json` ([`resilience::FailureReport`]) with the full
//!   recovery-event trail instead of dying silently.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fixedpoint;
pub mod macsim;
pub mod metrics;
pub mod policy;
pub mod resilience;
pub mod runtime;
pub mod telemetry;
pub mod testutil;
pub mod trainer;
pub mod util;

/// Canonical location of the AOT artifacts relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$QEDPS_ARTIFACTS`, else `./artifacts`,
/// else walk up from the current dir (so tests/examples work from anywhere
/// inside the repo).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("QEDPS_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
