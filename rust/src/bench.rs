//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean/stddev/min reporting and a plain
//! `name,mean_ns,stddev_ns,min_ns,iters` CSV-ish line for scripting.  Used
//! by every target in `rust/benches/` (`cargo bench` runs them via
//! `harness = false`).
//!
//! [`EvalBenchReport`] is the machine-readable record behind
//! `repro bench eval --json`: its key set is pinned by a unit test here so
//! downstream scripts can rely on the schema.

use crate::util::json::Json;
use crate::util::{Stopwatch, Summary};

pub struct BenchOpts {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub min_time_s: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup_iters: 3, min_iters: 10, min_time_s: 1.0 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn report(&self) {
        let (val, unit) = human_time(self.mean_ns);
        let (min, min_unit) = human_time(self.min_ns);
        crate::out!(
            "{:<44} {:>9.3} {:<2} (±{:>5.1}%, min {:>8.3} {}, n={})",
            self.name,
            val,
            unit,
            100.0 * self.stddev_ns / self.mean_ns.max(1e-12),
            min,
            min_unit,
            self.iters
        );
    }
}

fn human_time(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Time `f` (whole-call granularity) under the default opts.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_with(name, &BenchOpts::default(), f)
}

pub fn bench_with<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut stats = Summary::new();
    let total = Stopwatch::start();
    let mut iters = 0u64;
    while iters < opts.min_iters || total.elapsed_s() < opts.min_time_s {
        let t = Stopwatch::start();
        f();
        stats.add(t.elapsed_s() * 1e9);
        iters += 1;
        if iters >= 1_000_000 {
            break;
        }
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats.mean(),
        stddev_ns: stats.stddev(),
        min_ns: stats.min,
    };
    r.report();
    r
}

/// Machine-readable record for `repro bench eval --json`.
///
/// The counter fields are **deltas across the timed eval loop only**
/// (warmup passes excluded): a healthy cached-eval steady state reports
/// `literal_builds == 0` and `h2d_input == 0` on every platform, plus
/// `h2d_state == 0` and `host_transfers == 0` when the parameter state is
/// device-resident.
#[derive(Debug, Clone)]
pub struct EvalBenchReport {
    pub model: String,
    pub scheme: String,
    /// Timed eval passes (full sweeps over the test set).
    pub passes: u64,
    pub batches_per_pass: usize,
    /// Test-set size (deliberately not a multiple of the eval batch, so the
    /// tail-mask path is always exercised).
    pub examples: usize,
    pub mean_pass_ns: f64,
    pub stddev_pass_ns: f64,
    pub min_pass_ns: f64,
    pub literal_builds: u64,
    pub h2d_state: u64,
    pub h2d_input: u64,
    pub host_transfers: u64,
    pub device_resident: bool,
    /// Full telemetry counter/span delta over the timed loop.
    pub telemetry: Json,
}

impl EvalBenchReport {
    /// The pinned `bench eval --json` schema (see `eval_bench_json_schema`
    /// in this module's tests before renaming anything).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("eval".into())),
            ("model", Json::Str(self.model.clone())),
            ("scheme", Json::Str(self.scheme.clone())),
            ("passes", Json::Num(self.passes as f64)),
            ("batches_per_pass", Json::Num(self.batches_per_pass as f64)),
            ("examples", Json::Num(self.examples as f64)),
            ("mean_pass_ns", Json::Num(self.mean_pass_ns)),
            ("stddev_pass_ns", Json::Num(self.stddev_pass_ns)),
            ("min_pass_ns", Json::Num(self.min_pass_ns)),
            ("literal_builds", Json::Num(self.literal_builds as f64)),
            ("h2d_state", Json::Num(self.h2d_state as f64)),
            ("h2d_input", Json::Num(self.h2d_input as f64)),
            ("host_transfers", Json::Num(self.host_transfers as f64)),
            ("device_resident", Json::Bool(self.device_resident)),
            ("telemetry", self.telemetry.clone()),
        ])
    }
}

/// Throughput helper: report elements/s alongside the timing.
pub fn report_throughput(r: &BenchResult, elems: usize) {
    let eps = elems as f64 / (r.mean_ns / 1e9);
    crate::out!(
        "{:<44} {:>9.1} Melem/s",
        format!("{} (throughput)", r.name),
        eps / 1e6
    );
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let opts = BenchOpts { warmup_iters: 1, min_iters: 5, min_time_s: 0.0 };
        let mut acc = 0u64;
        let r = bench_with("noop-ish", &opts, || {
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns);
    }

    #[test]
    fn eval_bench_json_schema() {
        // Pin the `bench eval --json` key set the way scripts consume it:
        // adding a key is fine (extend this list); renaming or dropping one
        // is a breaking change and must fail here first.
        let report = EvalBenchReport {
            model: "mlp".into(),
            scheme: "qedps".into(),
            passes: 5,
            batches_per_pass: 3,
            examples: 333,
            mean_pass_ns: 1.5e6,
            stddev_pass_ns: 2.0e4,
            min_pass_ns: 1.4e6,
            literal_builds: 0,
            h2d_state: 0,
            h2d_input: 0,
            host_transfers: 0,
            device_resident: true,
            telemetry: Json::obj(vec![]),
        };
        let j = report.to_json();
        let obj = j.as_obj().expect("report serializes to an object");
        let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        assert_eq!(
            keys,
            [
                "batches_per_pass",
                "bench",
                "device_resident",
                "examples",
                "h2d_input",
                "h2d_state",
                "host_transfers",
                "literal_builds",
                "mean_pass_ns",
                "min_pass_ns",
                "model",
                "passes",
                "scheme",
                "stddev_pass_ns",
                "telemetry",
            ],
            "bench eval --json schema changed"
        );
        assert_eq!(obj.get("bench").and_then(|v| v.as_str()), Some("eval"));
        assert_eq!(obj.get("device_resident").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(obj.get("examples").and_then(|v| v.as_usize()), Some(333));
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(500.0).1, "ns");
        assert_eq!(human_time(5e4).1, "µs");
        assert_eq!(human_time(5e7).1, "ms");
        assert_eq!(human_time(5e9).1, "s");
    }
}
