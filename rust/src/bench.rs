//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warmup + timed iterations with mean/stddev/min reporting and a plain
//! `name,mean_ns,stddev_ns,min_ns,iters` CSV-ish line for scripting.  Used
//! by every target in `rust/benches/` (`cargo bench` runs them via
//! `harness = false`).

use crate::util::{Stopwatch, Summary};

pub struct BenchOpts {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub min_time_s: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self { warmup_iters: 3, min_iters: 10, min_time_s: 1.0 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn report(&self) {
        let (val, unit) = human_time(self.mean_ns);
        let (min, min_unit) = human_time(self.min_ns);
        crate::out!(
            "{:<44} {:>9.3} {:<2} (±{:>5.1}%, min {:>8.3} {}, n={})",
            self.name,
            val,
            unit,
            100.0 * self.stddev_ns / self.mean_ns.max(1e-12),
            min,
            min_unit,
            self.iters
        );
    }
}

fn human_time(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "µs")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Time `f` (whole-call granularity) under the default opts.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_with(name, &BenchOpts::default(), f)
}

pub fn bench_with<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut stats = Summary::new();
    let total = Stopwatch::start();
    let mut iters = 0u64;
    while iters < opts.min_iters || total.elapsed_s() < opts.min_time_s {
        let t = Stopwatch::start();
        f();
        stats.add(t.elapsed_s() * 1e9);
        iters += 1;
        if iters >= 1_000_000 {
            break;
        }
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats.mean(),
        stddev_ns: stats.stddev(),
        min_ns: stats.min,
    };
    r.report();
    r
}

/// Throughput helper: report elements/s alongside the timing.
pub fn report_throughput(r: &BenchResult, elems: usize) {
    let eps = elems as f64 / (r.mean_ns / 1e9);
    crate::out!(
        "{:<44} {:>9.1} Melem/s",
        format!("{} (throughput)", r.name),
        eps / 1e6
    );
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let opts = BenchOpts { warmup_iters: 1, min_iters: 5, min_time_s: 0.0 };
        let mut acc = 0u64;
        let r = bench_with("noop-ish", &opts, || {
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.mean_ns);
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(500.0).1, "ns");
        assert_eq!(human_time(5e4).1, "µs");
        assert_eq!(human_time(5e7).1, "ms");
        assert_eq!(human_time(5e9).1, "s");
    }
}
