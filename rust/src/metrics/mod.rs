//! Training/eval metric records, the run history, and CSV/JSON export —
//! the data behind every regenerated figure.

use std::path::Path;

use crate::policy::PrecState;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;

/// One logged training iteration.
#[derive(Debug, Clone)]
pub struct TrainRecord {
    pub iter: u64,
    pub loss: f32,
    pub acc: f32,
    pub lr: f64,
    pub prec: PrecState,
    /// Aggregated per-class stats [weights, acts, grads].
    pub e: [f32; 3],
    pub r: [f32; 3],
    pub step_ms: f64,
}

/// One test-set evaluation.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub iter: u64,
    pub test_loss: f32,
    pub test_acc: f32,
}

/// One resilience action taken during the run (watchdog trip + rollback,
/// injected fault, resume, abort) — exported alongside the summary so
/// recoveries are auditable after the fact.
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// Iteration at which the event fired.
    pub iter: u64,
    /// Stable tag: `non_finite_loss`, `loss_explosion`, `sustained_overflow`,
    /// `fault_loss`, `fault_bitflip`, `resume`, `abort`.
    pub kind: String,
    /// Human-readable description.
    pub detail: String,
    /// Iteration the run rewound to, when this event rolled the run back
    /// (`None` for purely informational events: injected faults, resume,
    /// abort).
    pub rollback_to: Option<u64>,
}

/// Full history of a run.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub scheme: String,
    pub model: String,
    pub train: Vec<TrainRecord>,
    pub eval: Vec<EvalRecord>,
    pub recovery: Vec<RecoveryEvent>,
    /// This run's telemetry delta (counters + span histograms), captured by
    /// [`crate::trainer::Session`] at run end; `None` for histories built
    /// outside a session (unit tests, hand-rolled loops).
    pub telemetry: Option<crate::telemetry::Snapshot>,
}

/// How a run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunStatus {
    /// Trained to the end with a finite final loss.
    Ok,
    /// No training iterations were recorded (e.g. aborted before step 1).
    Incomplete,
    /// The final recorded loss is non-finite.
    Diverged,
}

impl RunStatus {
    pub fn as_str(&self) -> &'static str {
        match self {
            RunStatus::Ok => "ok",
            RunStatus::Incomplete => "incomplete",
            RunStatus::Diverged => "diverged",
        }
    }
}

/// The numbers the paper's abstract quotes (avg bit-widths + accuracy).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub status: RunStatus,
    pub final_test_acc: f32,
    pub best_test_acc: f32,
    /// Meaningful only when `status != Incomplete` (0.0 on an empty run —
    /// the status field, not a NaN sentinel, marks the run incomplete).
    pub final_train_loss: f32,
    pub mean_weight_bits: f64,
    pub mean_act_bits: f64,
    pub mean_grad_bits: f64,
    pub min_weight_bits: i32,
    pub min_act_bits: i32,
    pub mean_step_ms: f64,
    /// Nearest-rank p95 of the logged per-iteration step times.
    pub p95_step_ms: f64,
    pub iters: u64,
    /// Watchdog rollbacks performed during the run.
    pub recoveries: u64,
    /// Watchdog trips observed (rollbacks plus a final abort, if any);
    /// injected faults and resumes are informational and do not count.
    pub watchdog_trips: u64,
}

/// Recovery-event kinds that mean the watchdog fired.
const TRIP_KINDS: [&str; 4] =
    ["non_finite_loss", "loss_explosion", "sustained_overflow", "abort"];

/// Nearest-rank quantile of an unsorted sample (0.0 when empty).
fn quantile(mut vals: Vec<f64>, q: f64) -> f64 {
    if vals.is_empty() {
        return 0.0;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = ((q.clamp(0.0, 1.0) * vals.len() as f64).ceil() as usize).max(1);
    vals[rank - 1]
}

impl History {
    pub fn new(scheme: &str, model: &str) -> Self {
        Self { scheme: scheme.into(), model: model.into(), ..Default::default() }
    }

    pub fn summary(&self) -> RunSummary {
        let n = self.train.len().max(1) as f64;
        let mean = |f: &dyn Fn(&TrainRecord) -> f64| -> f64 {
            self.train.iter().map(|r| f(r)).sum::<f64>() / n
        };
        let status = match self.train.last() {
            None => RunStatus::Incomplete,
            Some(r) if !r.loss.is_finite() => RunStatus::Diverged,
            Some(_) => RunStatus::Ok,
        };
        RunSummary {
            status,
            final_test_acc: self.eval.last().map(|e| e.test_acc).unwrap_or(0.0),
            best_test_acc: self
                .eval
                .iter()
                .map(|e| e.test_acc)
                .fold(0.0, f32::max),
            final_train_loss: self.train.last().map(|r| r.loss).unwrap_or(0.0),
            mean_weight_bits: mean(&|r| r.prec.weights.bits() as f64),
            mean_act_bits: mean(&|r| r.prec.acts.bits() as f64),
            mean_grad_bits: mean(&|r| r.prec.grads.bits() as f64),
            min_weight_bits: self
                .train
                .iter()
                .map(|r| r.prec.weights.bits())
                .min()
                .unwrap_or(0),
            min_act_bits: self
                .train
                .iter()
                .map(|r| r.prec.acts.bits())
                .min()
                .unwrap_or(0),
            mean_step_ms: mean(&|r| r.step_ms),
            p95_step_ms: quantile(
                self.train.iter().map(|r| r.step_ms).collect(),
                0.95,
            ),
            iters: self.train.last().map(|r| r.iter + 1).unwrap_or(0),
            recoveries: self
                .recovery
                .iter()
                .filter(|e| e.rollback_to.is_some())
                .count() as u64,
            watchdog_trips: self
                .recovery
                .iter()
                .filter(|e| TRIP_KINDS.contains(&e.kind.as_str()))
                .count() as u64,
        }
    }

    /// The recovery-event trail as a JSON array (also embedded in
    /// [`Self::summary_json`] and in failure reports).
    pub fn recovery_json(&self) -> Json {
        Json::Arr(
            self.recovery
                .iter()
                .map(|e| {
                    Json::obj(vec![
                        ("iter", Json::Num(e.iter as f64)),
                        ("kind", Json::Str(e.kind.clone())),
                        ("detail", Json::Str(e.detail.clone())),
                        (
                            "rollback_to",
                            e.rollback_to
                                .map(|i| Json::Num(i as f64))
                                .unwrap_or(Json::Null),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// Figure-3 / figure-4 CSV: one row per logged iteration.
    pub fn write_train_csv<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "iter", "loss", "acc", "lr", "il_w", "fl_w", "bits_w", "il_a",
                "fl_a", "bits_a", "il_g", "fl_g", "bits_g", "e_w", "e_a",
                "e_g", "r_w", "r_a", "r_g", "step_ms",
            ],
        )?;
        for r in &self.train {
            w.row(&[
                r.iter as f64,
                r.loss as f64,
                r.acc as f64,
                r.lr,
                r.prec.weights.il as f64,
                r.prec.weights.fl as f64,
                r.prec.weights.bits() as f64,
                r.prec.acts.il as f64,
                r.prec.acts.fl as f64,
                r.prec.acts.bits() as f64,
                r.prec.grads.il as f64,
                r.prec.grads.fl as f64,
                r.prec.grads.bits() as f64,
                r.e[0] as f64,
                r.e[1] as f64,
                r.e[2] as f64,
                r.r[0] as f64,
                r.r[1] as f64,
                r.r[2] as f64,
                r.step_ms,
            ])?;
        }
        w.flush()
    }

    pub fn write_eval_csv<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(path, &["iter", "test_loss", "test_acc"])?;
        for e in &self.eval {
            w.row(&[e.iter as f64, e.test_loss as f64, e.test_acc as f64])?;
        }
        w.flush()
    }

    /// JSON blob with the summary (machine-readable experiment record).
    pub fn summary_json(&self) -> Json {
        let s = self.summary();
        Json::obj(vec![
            ("scheme", Json::Str(self.scheme.clone())),
            ("model", Json::Str(self.model.clone())),
            ("status", Json::Str(s.status.as_str().into())),
            ("iters", Json::Num(s.iters as f64)),
            ("final_test_acc", Json::Num(s.final_test_acc as f64)),
            ("best_test_acc", Json::Num(s.best_test_acc as f64)),
            ("final_train_loss", Json::Num(s.final_train_loss as f64)),
            ("mean_weight_bits", Json::Num(s.mean_weight_bits)),
            ("mean_act_bits", Json::Num(s.mean_act_bits)),
            ("mean_grad_bits", Json::Num(s.mean_grad_bits)),
            ("min_weight_bits", Json::Num(s.min_weight_bits as f64)),
            ("min_act_bits", Json::Num(s.min_act_bits as f64)),
            ("mean_step_ms", Json::Num(s.mean_step_ms)),
            ("p95_step_ms", Json::Num(s.p95_step_ms)),
            ("recoveries", Json::Num(s.recoveries as f64)),
            ("watchdog_trips", Json::Num(s.watchdog_trips as f64)),
            ("recovery_events", self.recovery_json()),
            (
                "telemetry",
                self.telemetry
                    .as_ref()
                    .map(|t| t.to_json())
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Format;

    fn rec(iter: u64, bits: i32) -> TrainRecord {
        TrainRecord {
            iter,
            loss: 1.0 / (iter + 1) as f32,
            acc: 0.5,
            lr: 0.01,
            prec: PrecState::uniform(Format::new(bits / 2, bits - bits / 2)),
            e: [0.0; 3],
            r: [0.0; 3],
            step_ms: 10.0,
        }
    }

    #[test]
    fn summary_averages_bits() {
        let mut h = History::new("qedps", "lenet");
        h.train.push(rec(0, 16));
        h.train.push(rec(1, 12));
        h.eval.push(EvalRecord { iter: 1, test_loss: 0.5, test_acc: 0.9 });
        h.eval.push(EvalRecord { iter: 2, test_loss: 0.4, test_acc: 0.85 });
        let s = h.summary();
        assert_eq!(s.mean_weight_bits, 14.0);
        assert_eq!(s.min_weight_bits, 12);
        assert_eq!(s.final_test_acc, 0.85);
        assert_eq!(s.best_test_acc, 0.9);
        assert_eq!(s.iters, 2);
    }

    #[test]
    fn csv_roundtrip() {
        let mut h = History::new("qedps", "mlp");
        for i in 0..5 {
            h.train.push(rec(i, 16));
        }
        let dir = std::env::temp_dir().join("qedps_metrics_test");
        let path = dir.join("train.csv");
        h.write_train_csv(&path).unwrap();
        let (header, rows) = crate::util::csv::read_csv(&path).unwrap();
        assert_eq!(header[0], "iter");
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[3][0], 3.0);
    }

    #[test]
    fn summary_json_has_headline_fields() {
        let mut h = History::new("qedps", "lenet");
        h.train.push(rec(0, 16));
        let j = h.summary_json();
        assert!(j.get("mean_weight_bits").as_f64().is_some());
        assert_eq!(j.get("scheme").as_str(), Some("qedps"));
        assert_eq!(j.get("status").as_str(), Some("ok"));
    }

    #[test]
    fn empty_run_is_incomplete_not_nan() {
        let h = History::new("qedps", "lenet");
        let s = h.summary();
        assert_eq!(s.status, RunStatus::Incomplete);
        assert!(s.final_train_loss.is_finite(), "no NaN sentinel");
        let j = h.summary_json();
        assert_eq!(j.get("status").as_str(), Some("incomplete"));
        assert_eq!(j.get("final_train_loss").as_f64(), Some(0.0));
    }

    #[test]
    fn non_finite_final_loss_is_diverged() {
        let mut h = History::new("fixed", "mlp");
        let mut r = rec(0, 13);
        r.loss = f32::NAN;
        h.train.push(r);
        assert_eq!(h.summary().status, RunStatus::Diverged);
        assert_eq!(h.summary_json().get("status").as_str(), Some("diverged"));
    }

    #[test]
    fn recovery_events_export_and_count() {
        let mut h = History::new("qedps", "mlp");
        h.train.push(rec(0, 16));
        h.recovery.push(RecoveryEvent {
            iter: 3,
            kind: "fault_loss".into(),
            detail: "injected NaN".into(),
            rollback_to: None,
        });
        h.recovery.push(RecoveryEvent {
            iter: 3,
            kind: "non_finite_loss".into(),
            detail: "loss is not finite (NaN)".into(),
            rollback_to: Some(0),
        });
        let s = h.summary();
        assert_eq!(s.recoveries, 1, "only rollbacks count as recoveries");
        assert_eq!(s.watchdog_trips, 1, "the injected fault is not a trip");
        let j = h.summary_json();
        assert_eq!(j.get("recoveries").as_f64(), Some(1.0));
        assert_eq!(j.get("watchdog_trips").as_f64(), Some(1.0));
        let ev = j.get("recovery_events");
        assert_eq!(ev.at(0).get("kind").as_str(), Some("fault_loss"));
        assert!(ev.at(0).get("rollback_to").is_null());
        assert_eq!(ev.at(1).get("rollback_to").as_f64(), Some(0.0));
        // survives a JSON round-trip
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back.get("recovery_events").at(1).get("kind").as_str(),
                   Some("non_finite_loss"));
    }

    #[test]
    fn recovery_json_roundtrips_through_util_json() {
        let mut h = History::new("qedps", "mlp");
        h.recovery.push(RecoveryEvent {
            iter: 7,
            kind: "loss_explosion".into(),
            detail: "loss exploded (9.0 vs baseline 1.0)".into(),
            rollback_to: Some(4),
        });
        h.recovery.push(RecoveryEvent {
            iter: 9,
            kind: "resume".into(),
            detail: "resumed from checkpoint at iter 8".into(),
            rollback_to: None,
        });
        let text = h.recovery_json().to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 2);
        assert_eq!(back.at(0).get("iter").as_f64(), Some(7.0));
        assert_eq!(back.at(0).get("kind").as_str(), Some("loss_explosion"));
        assert_eq!(back.at(0).get("rollback_to").as_f64(), Some(4.0));
        assert_eq!(
            back.at(1).get("detail").as_str(),
            Some("resumed from checkpoint at iter 8")
        );
        assert!(back.at(1).get("rollback_to").is_null());
        // an empty trail is an empty array, not null
        assert_eq!(History::new("a", "b").recovery_json(), Json::Arr(vec![]));
    }

    #[test]
    fn p95_step_ms_is_nearest_rank() {
        let mut h = History::new("qedps", "mlp");
        for i in 0..20 {
            let mut r = rec(i, 16);
            r.step_ms = (i + 1) as f64; // 1..=20
            h.train.push(r);
        }
        let s = h.summary();
        assert_eq!(s.p95_step_ms, 19.0, "ceil(0.95*20) = rank 19");
        assert!((s.mean_step_ms - 10.5).abs() < 1e-12);
        assert_eq!(h.summary_json().get("p95_step_ms").as_f64(), Some(19.0));
        assert_eq!(History::new("a", "b").summary().p95_step_ms, 0.0);
    }

    #[test]
    fn telemetry_block_roundtrips_in_summary_json() {
        let mut h = History::new("qedps", "mlp");
        h.train.push(rec(0, 16));
        assert!(
            h.summary_json().get("telemetry").is_null(),
            "histories without a session carry no telemetry"
        );

        let base = crate::telemetry::snapshot();
        crate::telemetry::count("test.metrics_counter", 3);
        {
            let _s = crate::telemetry::span!("test.metrics_span");
        }
        h.telemetry = Some(crate::telemetry::snapshot().diff(&base));

        let text = h.summary_json().to_string_pretty();
        let back = Json::parse(&text).unwrap();
        let snap =
            crate::telemetry::Snapshot::from_json(back.get("telemetry")).unwrap();
        assert_eq!(snap.counter("test.metrics_counter"), 3);
        assert_eq!(
            snap.spans().get("test.metrics_span").map(|s| s.count()),
            Some(1)
        );
    }
}
