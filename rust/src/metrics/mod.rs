//! Training/eval metric records, the run history, and CSV/JSON export —
//! the data behind every regenerated figure.

use std::path::Path;

use crate::policy::PrecState;
use crate::util::csv::CsvWriter;
use crate::util::json::Json;

/// One logged training iteration.
#[derive(Debug, Clone)]
pub struct TrainRecord {
    pub iter: u64,
    pub loss: f32,
    pub acc: f32,
    pub lr: f64,
    pub prec: PrecState,
    /// Aggregated per-class stats [weights, acts, grads].
    pub e: [f32; 3],
    pub r: [f32; 3],
    pub step_ms: f64,
}

/// One test-set evaluation.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub iter: u64,
    pub test_loss: f32,
    pub test_acc: f32,
}

/// Full history of a run.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub scheme: String,
    pub model: String,
    pub train: Vec<TrainRecord>,
    pub eval: Vec<EvalRecord>,
}

/// The numbers the paper's abstract quotes (avg bit-widths + accuracy).
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub final_test_acc: f32,
    pub best_test_acc: f32,
    pub final_train_loss: f32,
    pub mean_weight_bits: f64,
    pub mean_act_bits: f64,
    pub mean_grad_bits: f64,
    pub min_weight_bits: i32,
    pub min_act_bits: i32,
    pub mean_step_ms: f64,
    pub iters: u64,
}

impl History {
    pub fn new(scheme: &str, model: &str) -> Self {
        Self { scheme: scheme.into(), model: model.into(), ..Default::default() }
    }

    pub fn summary(&self) -> RunSummary {
        let n = self.train.len().max(1) as f64;
        let mean = |f: &dyn Fn(&TrainRecord) -> f64| -> f64 {
            self.train.iter().map(|r| f(r)).sum::<f64>() / n
        };
        RunSummary {
            final_test_acc: self.eval.last().map(|e| e.test_acc).unwrap_or(0.0),
            best_test_acc: self
                .eval
                .iter()
                .map(|e| e.test_acc)
                .fold(0.0, f32::max),
            final_train_loss: self.train.last().map(|r| r.loss).unwrap_or(f32::NAN),
            mean_weight_bits: mean(&|r| r.prec.weights.bits() as f64),
            mean_act_bits: mean(&|r| r.prec.acts.bits() as f64),
            mean_grad_bits: mean(&|r| r.prec.grads.bits() as f64),
            min_weight_bits: self
                .train
                .iter()
                .map(|r| r.prec.weights.bits())
                .min()
                .unwrap_or(0),
            min_act_bits: self
                .train
                .iter()
                .map(|r| r.prec.acts.bits())
                .min()
                .unwrap_or(0),
            mean_step_ms: mean(&|r| r.step_ms),
            iters: self.train.last().map(|r| r.iter + 1).unwrap_or(0),
        }
    }

    /// Figure-3 / figure-4 CSV: one row per logged iteration.
    pub fn write_train_csv<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(
            path,
            &[
                "iter", "loss", "acc", "lr", "il_w", "fl_w", "bits_w", "il_a",
                "fl_a", "bits_a", "il_g", "fl_g", "bits_g", "e_w", "e_a",
                "e_g", "r_w", "r_a", "r_g", "step_ms",
            ],
        )?;
        for r in &self.train {
            w.row(&[
                r.iter as f64,
                r.loss as f64,
                r.acc as f64,
                r.lr,
                r.prec.weights.il as f64,
                r.prec.weights.fl as f64,
                r.prec.weights.bits() as f64,
                r.prec.acts.il as f64,
                r.prec.acts.fl as f64,
                r.prec.acts.bits() as f64,
                r.prec.grads.il as f64,
                r.prec.grads.fl as f64,
                r.prec.grads.bits() as f64,
                r.e[0] as f64,
                r.e[1] as f64,
                r.e[2] as f64,
                r.r[0] as f64,
                r.r[1] as f64,
                r.r[2] as f64,
                r.step_ms,
            ])?;
        }
        w.flush()
    }

    pub fn write_eval_csv<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let mut w = CsvWriter::create(path, &["iter", "test_loss", "test_acc"])?;
        for e in &self.eval {
            w.row(&[e.iter as f64, e.test_loss as f64, e.test_acc as f64])?;
        }
        w.flush()
    }

    /// JSON blob with the summary (machine-readable experiment record).
    pub fn summary_json(&self) -> Json {
        let s = self.summary();
        Json::obj(vec![
            ("scheme", Json::Str(self.scheme.clone())),
            ("model", Json::Str(self.model.clone())),
            ("iters", Json::Num(s.iters as f64)),
            ("final_test_acc", Json::Num(s.final_test_acc as f64)),
            ("best_test_acc", Json::Num(s.best_test_acc as f64)),
            ("final_train_loss", Json::Num(s.final_train_loss as f64)),
            ("mean_weight_bits", Json::Num(s.mean_weight_bits)),
            ("mean_act_bits", Json::Num(s.mean_act_bits)),
            ("mean_grad_bits", Json::Num(s.mean_grad_bits)),
            ("min_weight_bits", Json::Num(s.min_weight_bits as f64)),
            ("min_act_bits", Json::Num(s.min_act_bits as f64)),
            ("mean_step_ms", Json::Num(s.mean_step_ms)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::Format;

    fn rec(iter: u64, bits: i32) -> TrainRecord {
        TrainRecord {
            iter,
            loss: 1.0 / (iter + 1) as f32,
            acc: 0.5,
            lr: 0.01,
            prec: PrecState::uniform(Format::new(bits / 2, bits - bits / 2)),
            e: [0.0; 3],
            r: [0.0; 3],
            step_ms: 10.0,
        }
    }

    #[test]
    fn summary_averages_bits() {
        let mut h = History::new("qedps", "lenet");
        h.train.push(rec(0, 16));
        h.train.push(rec(1, 12));
        h.eval.push(EvalRecord { iter: 1, test_loss: 0.5, test_acc: 0.9 });
        h.eval.push(EvalRecord { iter: 2, test_loss: 0.4, test_acc: 0.85 });
        let s = h.summary();
        assert_eq!(s.mean_weight_bits, 14.0);
        assert_eq!(s.min_weight_bits, 12);
        assert_eq!(s.final_test_acc, 0.85);
        assert_eq!(s.best_test_acc, 0.9);
        assert_eq!(s.iters, 2);
    }

    #[test]
    fn csv_roundtrip() {
        let mut h = History::new("qedps", "mlp");
        for i in 0..5 {
            h.train.push(rec(i, 16));
        }
        let dir = std::env::temp_dir().join("qedps_metrics_test");
        let path = dir.join("train.csv");
        h.write_train_csv(&path).unwrap();
        let (header, rows) = crate::util::csv::read_csv(&path).unwrap();
        assert_eq!(header[0], "iter");
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[3][0], 3.0);
    }

    #[test]
    fn summary_json_has_headline_fields() {
        let mut h = History::new("qedps", "lenet");
        h.train.push(rec(0, 16));
        let j = h.summary_json();
        assert!(j.get("mean_weight_bits").as_f64().is_some());
        assert_eq!(j.get("scheme").as_str(), Some("qedps"));
    }
}
