//! Figure/table regeneration drivers (one per paper artifact — DESIGN §3).
//!
//! Every driver writes CSV series under `cfg.out_dir` and prints an ASCII
//! rendition so a terminal run shows the *shape* the paper reports.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::macsim::{self, MacUnit};
use crate::metrics::History;
use crate::policy::PrecState;
use crate::runtime::Runtime;

/// **Figure 3**: bit-width trajectories (weights & activations) for the
/// qedps run vs the 32-bit baseline.  Reuses the Fig-4 qedps run.
pub fn fig3(rt: &mut Runtime, cfg: &ExperimentConfig) -> Result<History> {
    let mut c = cfg.clone();
    c.scheme = "qedps".into();
    let hist = super::run_and_record(rt, &c, &format!("fig3_{}", c.model))?;
    crate::out!();
    crate::out!("Figure 3 — bit-width over training (weights / activations / grads)");
    ascii_series(
        &hist
            .train
            .iter()
            .map(|r| (r.iter as f64, r.prec.weights.bits() as f64))
            .collect::<Vec<_>>(),
        "weights bits",
        32.0,
    );
    ascii_series(
        &hist
            .train
            .iter()
            .map(|r| (r.iter as f64, r.prec.acts.bits() as f64))
            .collect::<Vec<_>>(),
        "activations bits",
        32.0,
    );
    let s = hist.summary();
    crate::out!(
        "mean bits: weights={:.1} acts={:.1} grads={:.1}  (paper: ~16 / ~14 / near-full)",
        s.mean_weight_bits, s.mean_act_bits, s.mean_grad_bits
    );
    Ok(hist)
}

/// The Fig-4 scheme lineup: DPS vs float32 vs fixed-13-bit.
pub const FIG4_SCHEMES: [&str; 3] = ["qedps", "float", "fixed13"];

fn fig4_one(rt: &mut Runtime, cfg: &ExperimentConfig, scheme: &str) -> Result<History> {
    let mut c = cfg.clone();
    c.scheme = scheme.into();
    if let Some(d) = &cfg.checkpoint_dir {
        c.checkpoint_dir = Some(format!("{d}/fig4_{}_{scheme}", c.model));
    }
    super::run_and_record(rt, &c, &format!("fig4_{}_{scheme}", c.model))
}

fn render_fig4(out: &[(String, History)]) {
    crate::out!();
    crate::out!("Figure 4 — test accuracy: DPS vs float vs fixed-13");
    for (scheme, hist) in out {
        let series: Vec<(f64, f64)> = hist
            .eval
            .iter()
            .map(|e| (e.iter as f64, e.test_acc as f64))
            .collect();
        ascii_series(&series, &format!("{scheme} test acc"), 1.0);
        let s = hist.summary();
        crate::out!("  {scheme}: final={:.4} best={:.4}", s.final_test_acc, s.best_test_acc);
    }
}

/// **Figure 4**: accuracy curves, run serially on the caller's runtime.
pub fn fig4(rt: &mut Runtime, cfg: &ExperimentConfig) -> Result<Vec<(String, History)>> {
    let mut out = Vec::new();
    for scheme in FIG4_SCHEMES {
        out.push((scheme.to_string(), fig4_one(rt, cfg, scheme)?));
    }
    render_fig4(&out);
    Ok(out)
}

/// **Figure 4**, sharded: the three scheme runs are independent, so they
/// dispatch through [`super::sharder::run_sharded`] (`--jobs`/`--shard`)
/// and merge back in lineup order — identical output to [`fig4`].
pub fn fig4_sharded(
    cfg: &ExperimentConfig,
    opts: &super::ShardOpts,
) -> Result<Vec<(String, History)>> {
    let hists = super::sharder::run_sharded(&FIG4_SCHEMES, opts, |rt, _idx, scheme| {
        fig4_one(rt, cfg, scheme)
    })?;
    let out: Vec<(String, History)> = FIG4_SCHEMES
        .iter()
        .zip(hists)
        .filter_map(|(s, h)| h.map(|h| (s.to_string(), h)))
        .collect();
    render_fig4(&out);
    Ok(out)
}

/// The rounding A/B lineup (Eq.2 stochastic vs Eq.1 nearest).
pub const ROUNDING_TAGS: [&str; 2] = ["stochastic", "nearest"];

/// One arm of the rounding A/B: the `fixed` scheme at an aggressively
/// narrow format, with only the rounding artifact differing.
fn rounding_one(
    rt: &mut Runtime,
    cfg: &ExperimentConfig,
    tag: &str,
) -> Result<crate::metrics::RunSummary> {
    use crate::fixedpoint::Format;
    let mut c = cfg.clone();
    c.scheme = "fixed".into();
    c.init_weights = Format::new(2, 12);
    c.init_acts = Format::new(4, 10);
    c.init_grads = Format::new(2, 12);
    c.force_rounding = Some(tag.into());
    let run_tag = format!("roundab_{}_{tag}", c.model);
    // per-arm checkpoint subdir: concurrent arms must not cross-restore
    if let Some(d) = &cfg.checkpoint_dir {
        c.checkpoint_dir = Some(format!("{d}/{run_tag}"));
    }
    Ok(super::run_and_record(rt, &c, &run_tag)?.summary())
}

fn render_rounding(rows: &[(String, crate::metrics::RunSummary)]) {
    crate::out!();
    crate::out!("Rounding A/B (Eq.2 stochastic vs Eq.1 nearest):");
    for (tag, s) in rows {
        crate::out!(
            "  {tag:<11} final_acc={:.4} best={:.4} loss={:.4}",
            s.final_test_acc, s.best_test_acc, s.final_train_loss
        );
    }
}

/// Eq.1-vs-Eq.2 A/B (Gupta's stochastic-vs-nearest comparison): identical
/// policy and workload, only the rounding artifact differs — serially, on
/// the caller's runtime.
///
/// Run at an aggressively narrow *fixed* format — Gupta et al.'s result is
/// that nearest-rounding's bias (small gradient updates rounding to zero)
/// only bites when the fraction is short; at 20+ bits both round the same.
pub fn rounding_ab(
    rt: &mut Runtime,
    cfg: &ExperimentConfig,
) -> Result<Vec<(String, crate::metrics::RunSummary)>> {
    let mut rows = Vec::new();
    for tag in ROUNDING_TAGS {
        rows.push((tag.to_string(), rounding_one(rt, cfg, tag)?));
    }
    render_rounding(&rows);
    Ok(rows)
}

/// Rounding A/B, sharded: both arms are independent, so they dispatch
/// through [`super::sharder::run_sharded`] (`--jobs`/`--shard`) and merge
/// back in lineup order — identical output to [`rounding_ab`].
pub fn rounding_ab_sharded(
    cfg: &ExperimentConfig,
    opts: &super::ShardOpts,
) -> Result<Vec<(String, crate::metrics::RunSummary)>> {
    let sums = super::sharder::run_sharded(&ROUNDING_TAGS, opts, |rt, _idx, tag| {
        rounding_one(rt, cfg, tag)
    })?;
    let rows: Vec<(String, crate::metrics::RunSummary)> = ROUNDING_TAGS
        .iter()
        .zip(sums)
        .filter_map(|(t, s)| s.map(|s| (t.to_string(), s)))
        .collect();
    render_rounding(&rows);
    Ok(rows)
}

/// §6 hardware-speedup claim: measured bit trajectory → MAC-sim cycles.
pub fn history_speedup(rt: &Runtime, model: &str, hist: &History) -> Result<f64> {
    let layers = model_layers(rt, model)?;
    let unit = MacUnit::default();
    let traj: Vec<PrecState> = hist.train.iter().map(|r| r.prec).collect();
    if traj.is_empty() {
        return Ok(1.0);
    }
    Ok(macsim::trajectory_speedup(&unit, &layers, &traj))
}

/// MAC-count layer model from the manifest metadata.
pub fn model_layers(rt: &Runtime, model: &str) -> Result<Vec<macsim::LayerCost>> {
    let meta = rt.manifest.model(model)?;
    let params: Vec<(&str, Vec<usize>)> = meta
        .params
        .iter()
        .map(|p| (p.name.as_str(), p.shape.clone()))
        .collect();
    let hw = if meta.input_shape.len() >= 2 {
        (meta.input_shape[0], meta.input_shape[1])
    } else {
        (1, 1)
    };
    Ok(macsim::layer_costs(&params, hw, rt.manifest.train_batch))
}

/// Standalone MAC-sim report (no training): speedup vs word length table +
/// per-layer costs.
pub fn macsim_report(rt: &Runtime, model: &str) -> Result<()> {
    let layers = model_layers(rt, model)?;
    let unit = MacUnit::default();
    crate::out!();
    crate::out!("Flexible-MAC model — {model} @ batch {}", rt.manifest.train_batch);
    crate::out!("{:<10} {:>14}", "layer", "MACs/fwd");
    for l in &layers {
        crate::out!("{:<10} {:>14}", l.name, l.macs);
    }
    crate::out!();
    crate::out!("{:>6} {:>12} {:>10}", "bits", "cyc/iter", "speedup");
    for bits in [32, 24, 20, 16, 14, 12, 8, 4] {
        let p = PrecState::uniform(crate::fixedpoint::Format::new(bits / 2, bits - bits / 2));
        let cyc = macsim::iteration_cycles(&unit, &layers, &p);
        let base = macsim::iteration_cycles(
            &unit,
            &layers,
            &PrecState::uniform(crate::fixedpoint::Format::new(16, 16)),
        );
        crate::out!("{bits:>6} {cyc:>12} {:>9.2}x", base as f64 / cyc as f64);
    }
    Ok(())
}

/// Plain-terminal line plot: `series` = (x, y) pairs.
pub fn ascii_series(series: &[(f64, f64)], label: &str, ymax_hint: f64) {
    if series.is_empty() {
        crate::out!("  [{label}: no data]");
        return;
    }
    const W: usize = 72;
    const H: usize = 12;
    let xmax = series.last().unwrap().0.max(1.0);
    let ymax = series
        .iter()
        .map(|&(_, y)| y)
        .fold(0.0f64, f64::max)
        .max(ymax_hint * 0.25);
    let mut grid = vec![vec![b' '; W]; H];
    for &(x, y) in series {
        let col = ((x / xmax) * (W - 1) as f64).round() as usize;
        let row = if y.is_finite() {
            ((y / ymax) * (H - 1) as f64).round() as usize
        } else {
            continue;
        };
        let row = (H - 1).saturating_sub(row.min(H - 1));
        grid[row][col.min(W - 1)] = b'*';
    }
    crate::out!("  {label} (y: 0..{ymax:.1}, x: 0..{xmax:.0})");
    for row in grid {
        crate::out!("  |{}", String::from_utf8_lossy(&row));
    }
    crate::out!("  +{}", "-".repeat(W));
}

#[cfg(test)]
mod tests {
    #[test]
    fn ascii_series_handles_degenerate() {
        super::ascii_series(&[], "empty", 1.0);
        super::ascii_series(&[(0.0, 0.0)], "single", 1.0);
        super::ascii_series(&[(0.0, f64::NAN), (1.0, 1.0)], "nan", 1.0);
    }
}
