//! Deterministic fan-out of independent experiment runs.
//!
//! The paper's headline artifacts are *sweeps* — one run per scheme
//! (Table 1 / Fig. 4) or per rounding mode — and the runs share nothing
//! but the config template, so they shard trivially.  Two axes:
//!
//! - `--jobs N`: worker threads inside this process.  [`Runtime`] holds an
//!   `Rc` executable cache and is not `Send`, so each worker constructs its
//!   **own** runtime (PJRT client + compile cache) and pulls run indices
//!   off a shared atomic queue.
//! - `--shard i/n`: subprocess-level partitioning for multi-machine use.
//!   Shard *i* claims every index with `idx % n == i-1`; unclaimed indices
//!   come back as `None` and the caller merges tables across shards.
//!
//! Results are returned **indexed by input position**, never by completion
//! order, so merged CSV/JSON output is byte-identical whether a sweep ran
//! serially, threaded, or sharded — the determinism tests in
//! `tests/sharding_equivalence.rs` pin this down.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::runtime::Runtime;

/// One subprocess's slice of a sweep: this shard owns every run index with
/// `idx % of == index` (stored 0-based; parsed from 1-based `i/n`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub of: usize,
}

impl Shard {
    /// Parse `"i/n"` (1-based, e.g. `--shard 2/4` is the second of four).
    pub fn parse(s: &str) -> Result<Shard> {
        let (i, n) = s
            .split_once('/')
            .with_context(|| format!("--shard wants i/n, got '{s}'"))?;
        let i: usize = i.trim().parse().with_context(|| format!("shard index '{i}'"))?;
        let n: usize = n.trim().parse().with_context(|| format!("shard count '{n}'"))?;
        anyhow::ensure!(n >= 1, "shard count must be >= 1");
        anyhow::ensure!(
            (1..=n).contains(&i),
            "shard index {i} out of range 1..={n}"
        );
        Ok(Shard { index: i - 1, of: n })
    }

    pub fn selects(&self, idx: usize) -> bool {
        idx % self.of == self.index
    }
}

/// How to dispatch a sweep.
#[derive(Debug, Clone, Copy)]
pub struct ShardOpts {
    /// Worker threads (1 = run in the calling thread).
    pub jobs: usize,
    /// Optional subprocess-level partition.
    pub shard: Option<Shard>,
}

impl Default for ShardOpts {
    fn default() -> Self {
        ShardOpts { jobs: 1, shard: None }
    }
}

/// Runtime construction mutates process env (`XLA_FLAGS`) on first use;
/// serialize it so concurrent workers never race `set_var`/`var_os`.
static RUNTIME_INIT: Mutex<()> = Mutex::new(());

fn new_runtime() -> Result<Runtime> {
    let _guard = RUNTIME_INIT.lock().unwrap_or_else(|p| p.into_inner());
    Runtime::create()
}

/// Run `f` over every selected spec, each worker with its own [`Runtime`],
/// and return results **by input index** (deterministic merge regardless
/// of completion order).  Sharded-out indices are `None`.  The first run
/// error (or runtime-construction error) fails the whole sweep.
pub fn run_sharded<S, R, F>(specs: &[S], opts: &ShardOpts, f: F) -> Result<Vec<Option<R>>>
where
    S: Sync,
    R: Send,
    F: Fn(&mut Runtime, usize, &S) -> Result<R> + Sync,
{
    let selected: Vec<usize> = (0..specs.len())
        .filter(|&i| opts.shard.map(|s| s.selects(i)).unwrap_or(true))
        .collect();
    if let Some(s) = opts.shard {
        crate::log_info!(
            "sharder: shard {}/{} owns {} of {} runs",
            s.index + 1,
            s.of,
            selected.len(),
            specs.len()
        );
    }

    let mut slots: Vec<Option<Result<R>>> = Vec::with_capacity(specs.len());
    slots.resize_with(specs.len(), || None);

    let workers = opts.jobs.max(1).min(selected.len().max(1));
    if workers <= 1 {
        // serial path: same claim order, same merge semantics, one runtime
        let mut rt = new_runtime()?;
        for &idx in &selected {
            slots[idx] = Some(f(&mut rt, idx, &specs[idx]));
        }
    } else {
        let queue = AtomicUsize::new(0);
        let out = Mutex::new(&mut slots);
        // Telemetry registries are thread-local, so counters/spans recorded
        // inside a worker would vanish with its thread.  Each worker's final
        // snapshot *is* its delta (fresh thread = empty registry); collect
        // them and fold into the calling thread's registry below.
        let snaps: Mutex<Vec<(usize, crate::telemetry::Snapshot)>> =
            Mutex::new(Vec::with_capacity(workers));
        std::thread::scope(|scope| {
            for w in 0..workers {
                let queue = &queue;
                let out = &out;
                let selected = &selected;
                let f = &f;
                let snaps = &snaps;
                scope.spawn(move || {
                    // lazily built: a worker that never claims work never
                    // pays for a PJRT client
                    let mut rt: Option<Result<Runtime>> = None;
                    loop {
                        let k = queue.fetch_add(1, Ordering::Relaxed);
                        if k >= selected.len() {
                            break;
                        }
                        let idx = selected[k];
                        let res = match rt.get_or_insert_with(new_runtime) {
                            Ok(r) => f(r, idx, &specs[idx]),
                            Err(e) => Err(anyhow::anyhow!(
                                "worker {w}: creating runtime: {e:#}"
                            )),
                        };
                        let mut guard = out.lock().unwrap_or_else(|p| p.into_inner());
                        guard[idx] = Some(res);
                    }
                    let snap = crate::telemetry::snapshot();
                    if !snap.is_empty() {
                        let mut guard = snaps.lock().unwrap_or_else(|p| p.into_inner());
                        guard.push((w, snap));
                    }
                });
            }
        });
        // Merge in worker order (not completion order).  Addition is
        // commutative so the totals match a serial run regardless — the
        // sort just keeps the merge itself deterministic.
        let mut snaps = snaps.into_inner().unwrap_or_else(|p| p.into_inner());
        snaps.sort_by_key(|&(w, _)| w);
        for (_, snap) in &snaps {
            crate::telemetry::absorb(snap);
        }
    }

    let mut merged = Vec::with_capacity(specs.len());
    for (idx, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(Ok(r)) => merged.push(Some(r)),
            Some(Err(e)) => return Err(e.context(format!("sweep run {idx} failed"))),
            None => merged.push(None),
        }
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_parse_accepts_one_based() {
        assert_eq!(Shard::parse("1/3").unwrap(), Shard { index: 0, of: 3 });
        assert_eq!(Shard::parse("3/3").unwrap(), Shard { index: 2, of: 3 });
        assert_eq!(Shard::parse(" 2 / 4 ").unwrap(), Shard { index: 1, of: 4 });
        assert!(Shard::parse("0/3").is_err(), "index is 1-based");
        assert!(Shard::parse("4/3").is_err());
        assert!(Shard::parse("1/0").is_err());
        assert!(Shard::parse("nope").is_err());
        assert!(Shard::parse("1").is_err());
    }

    #[test]
    fn shards_partition_exactly() {
        // every index is owned by exactly one of the n shards
        for n in 1..=5 {
            let shards: Vec<Shard> = (1..=n)
                .map(|i| Shard::parse(&format!("{i}/{n}")).unwrap())
                .collect();
            for idx in 0..37 {
                let owners = shards.iter().filter(|s| s.selects(idx)).count();
                assert_eq!(owners, 1, "idx {idx} with {n} shards");
            }
        }
    }
}
