//! Experiment drivers: everything `repro <cmd>` runs to regenerate the
//! paper's figures and tables (DESIGN.md §3 experiment index).
//!
//! Multi-run sweeps (`compare`, Fig. 4) dispatch through
//! [`sharder::run_sharded`]: `--jobs N` fans runs out across worker
//! threads (each with its own [`Runtime`]), `--shard i/n` partitions a
//! sweep across subprocesses, and results always merge in input order so
//! the emitted tables are byte-identical to a serial run.

pub mod figures;
pub mod sharder;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::metrics::History;
use crate::runtime::Runtime;
use crate::trainer::run_experiment;
use crate::util::json::Json;

pub use sharder::{Shard, ShardOpts};

/// Run one configured experiment, write its CSV/JSON records, return the
/// history.
pub fn run_and_record(rt: &mut Runtime, cfg: &ExperimentConfig, tag: &str) -> Result<History> {
    let hist = run_experiment(rt, cfg)?;
    let dir = std::path::Path::new(&cfg.out_dir);
    std::fs::create_dir_all(dir)?;
    hist.write_train_csv(dir.join(format!("{tag}_train.csv")))?;
    hist.write_eval_csv(dir.join(format!("{tag}_eval.csv")))?;
    std::fs::write(
        dir.join(format!("{tag}_summary.json")),
        hist.summary_json().to_string_pretty(),
    )?;
    let s = hist.summary();
    crate::log_info!(
        "{tag}: final_acc={:.4} best_acc={:.4} mean_bits w={:.1} a={:.1} g={:.1}",
        s.final_test_acc, s.best_test_acc,
        s.mean_weight_bits, s.mean_act_bits, s.mean_grad_bits
    );
    Ok(hist)
}

/// Scheme-comparison row (Table 1 head-to-head).
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub scheme: String,
    pub final_acc: f32,
    pub best_acc: f32,
    pub mean_w_bits: f64,
    pub mean_a_bits: f64,
    pub mean_g_bits: f64,
    pub converged: bool,
    pub hw_speedup: f64,
    /// Watchdog trips observed during the run (PR-6 follow-up: surfaced in
    /// the table so a scheme that only finished by leaning on recovery is
    /// visible at a glance).
    pub watchdog_trips: u64,
    /// Rollbacks actually performed.
    pub recoveries: u64,
}

/// One scheme's comparison run: train, record, fold into a table row.
fn compare_one(rt: &mut Runtime, base: &ExperimentConfig, scheme: &str) -> Result<CompareRow> {
    let mut cfg = base.clone();
    cfg.scheme = scheme.to_string();
    let tag = format!("compare_{}_{scheme}", cfg.model);
    // per-scheme checkpoint subdir: concurrent runs must not share (or
    // cross-restore) rollback state
    if let Some(d) = &base.checkpoint_dir {
        cfg.checkpoint_dir = Some(format!("{d}/{tag}"));
    }
    let hist = run_and_record(rt, &cfg, &tag)?;
    let s = hist.summary();
    let speedup = figures::history_speedup(rt, &cfg.model, &hist)?;
    Ok(CompareRow {
        scheme: scheme.to_string(),
        final_acc: s.final_test_acc,
        best_acc: s.best_test_acc,
        mean_w_bits: s.mean_weight_bits,
        mean_a_bits: s.mean_act_bits,
        mean_g_bits: s.mean_grad_bits,
        // "converged" = ends well, not merely "passed through a good
        // state" (fixed-13 famously peaks then collapses — paper §5).
        converged: s.final_train_loss.is_finite() && s.final_test_acc > 0.5,
        hw_speedup: speedup,
        watchdog_trips: s.watchdog_trips,
        recoveries: s.recoveries,
    })
}

/// Run every scheme on the same workload (Table 1) and compute the MAC-sim
/// speedup of each measured trajectory — serially, on the caller's runtime.
pub fn compare_schemes(
    rt: &mut Runtime,
    base: &ExperimentConfig,
    schemes: &[&str],
) -> Result<Vec<CompareRow>> {
    schemes.iter().map(|s| compare_one(rt, base, s)).collect()
}

/// Sharded Table-1 sweep: independent scheme runs dispatched through
/// [`sharder::run_sharded`] (worker threads and/or a `--shard i/n` slice),
/// merged back in scheme order.  With `jobs = 1` and no shard this is
/// equivalent to [`compare_schemes`] — same rows, same bytes.
pub fn compare_schemes_sharded(
    base: &ExperimentConfig,
    schemes: &[&str],
    opts: &ShardOpts,
) -> Result<Vec<CompareRow>> {
    let rows = sharder::run_sharded(schemes, opts, |rt, _idx, scheme| {
        compare_one(rt, base, scheme)
    })?;
    Ok(rows.into_iter().flatten().collect())
}

pub fn print_compare_table(rows: &[CompareRow]) {
    println!(
        "\n{:<13} {:>9} {:>9} {:>8} {:>8} {:>8} {:>10} {:>9} {:>6} {:>6}",
        "scheme", "final_acc", "best_acc", "w_bits", "a_bits", "g_bits",
        "converged", "hw_speed", "trips", "recov"
    );
    println!("{}", "-".repeat(96));
    for r in rows {
        println!(
            "{:<13} {:>9.4} {:>9.4} {:>8.1} {:>8.1} {:>8.1} {:>10} {:>8.2}x {:>6} {:>6}",
            r.scheme, r.final_acc, r.best_acc, r.mean_w_bits, r.mean_a_bits,
            r.mean_g_bits, if r.converged { "yes" } else { "NO" }, r.hw_speedup,
            r.watchdog_trips, r.recoveries
        );
    }
    println!();
}

pub fn compare_rows_json(rows: &[CompareRow]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("scheme", Json::Str(r.scheme.clone())),
                    ("final_acc", Json::Num(r.final_acc as f64)),
                    ("best_acc", Json::Num(r.best_acc as f64)),
                    ("mean_w_bits", Json::Num(r.mean_w_bits)),
                    ("mean_a_bits", Json::Num(r.mean_a_bits)),
                    ("mean_g_bits", Json::Num(r.mean_g_bits)),
                    ("converged", Json::Bool(r.converged)),
                    ("hw_speedup", Json::Num(r.hw_speedup)),
                    ("watchdog_trips", Json::Num(r.watchdog_trips as f64)),
                    ("recoveries", Json::Num(r.recoveries as f64)),
                ])
            })
            .collect(),
    )
}
