//! Experiment drivers: everything `repro <cmd>` runs to regenerate the
//! paper's figures and tables (DESIGN.md §3 experiment index).
//!
//! Multi-run sweeps (`compare`, Fig. 4, the rounding A/B) dispatch through
//! [`sharder::run_sharded`]: `--jobs N` fans runs out across worker
//! threads (each with its own [`Runtime`]), `--shard i/n` partitions a
//! sweep across subprocesses, and results always merge in input order so
//! the emitted tables are byte-identical to a serial run.  Shard slices
//! written as `compare.shard-i-of-n.json` are rejoined by
//! [`merge_shard_slices`] (`repro compare merge`), which errors on
//! overlapping, duplicated, or missing shards instead of concatenating.

pub mod figures;
pub mod sharder;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::metrics::History;
use crate::runtime::Runtime;
use crate::trainer::run_experiment;
use crate::util::json::Json;

pub use sharder::{Shard, ShardOpts};

/// Run one configured experiment, write its CSV/JSON records, return the
/// history.
pub fn run_and_record(rt: &mut Runtime, cfg: &ExperimentConfig, tag: &str) -> Result<History> {
    let hist = run_experiment(rt, cfg)?;
    let dir = std::path::Path::new(&cfg.out_dir);
    std::fs::create_dir_all(dir)?;
    hist.write_train_csv(dir.join(format!("{tag}_train.csv")))?;
    hist.write_eval_csv(dir.join(format!("{tag}_eval.csv")))?;
    std::fs::write(
        dir.join(format!("{tag}_summary.json")),
        hist.summary_json().to_string_pretty(),
    )?;
    let s = hist.summary();
    crate::log_info!(
        "{tag}: final_acc={:.4} best_acc={:.4} mean_bits w={:.1} a={:.1} g={:.1}",
        s.final_test_acc, s.best_test_acc,
        s.mean_weight_bits, s.mean_act_bits, s.mean_grad_bits
    );
    Ok(hist)
}

/// Scheme-comparison row (Table 1 head-to-head).
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub scheme: String,
    pub final_acc: f32,
    pub best_acc: f32,
    pub mean_w_bits: f64,
    pub mean_a_bits: f64,
    pub mean_g_bits: f64,
    pub converged: bool,
    pub hw_speedup: f64,
    /// Watchdog trips observed during the run (PR-6 follow-up: surfaced in
    /// the table so a scheme that only finished by leaning on recovery is
    /// visible at a glance).
    pub watchdog_trips: u64,
    /// Rollbacks actually performed.
    pub recoveries: u64,
    /// Mean wall time per logged training step (ms).  Wall-clock columns
    /// are machine-dependent: the sharding-equivalence tests zero them
    /// before comparing tables byte-for-byte.
    pub mean_step_ms: f64,
    /// Nearest-rank p95 of the logged step times (ms).
    pub p95_step_ms: f64,
}

/// One scheme's comparison run: train, record, fold into a table row.
fn compare_one(rt: &mut Runtime, base: &ExperimentConfig, scheme: &str) -> Result<CompareRow> {
    let mut cfg = base.clone();
    cfg.scheme = scheme.to_string();
    let tag = format!("compare_{}_{scheme}", cfg.model);
    // per-scheme checkpoint subdir: concurrent runs must not share (or
    // cross-restore) rollback state
    if let Some(d) = &base.checkpoint_dir {
        cfg.checkpoint_dir = Some(format!("{d}/{tag}"));
    }
    let hist = run_and_record(rt, &cfg, &tag)?;
    let s = hist.summary();
    let speedup = figures::history_speedup(rt, &cfg.model, &hist)?;
    Ok(CompareRow {
        scheme: scheme.to_string(),
        final_acc: s.final_test_acc,
        best_acc: s.best_test_acc,
        mean_w_bits: s.mean_weight_bits,
        mean_a_bits: s.mean_act_bits,
        mean_g_bits: s.mean_grad_bits,
        // "converged" = ends well, not merely "passed through a good
        // state" (fixed-13 famously peaks then collapses — paper §5).
        converged: s.final_train_loss.is_finite() && s.final_test_acc > 0.5,
        hw_speedup: speedup,
        watchdog_trips: s.watchdog_trips,
        recoveries: s.recoveries,
        mean_step_ms: s.mean_step_ms,
        p95_step_ms: s.p95_step_ms,
    })
}

/// Run every scheme on the same workload (Table 1) and compute the MAC-sim
/// speedup of each measured trajectory — serially, on the caller's runtime.
pub fn compare_schemes(
    rt: &mut Runtime,
    base: &ExperimentConfig,
    schemes: &[&str],
) -> Result<Vec<CompareRow>> {
    schemes.iter().map(|s| compare_one(rt, base, s)).collect()
}

/// Sharded Table-1 sweep: independent scheme runs dispatched through
/// [`sharder::run_sharded`] (worker threads and/or a `--shard i/n` slice),
/// merged back in scheme order.  Results are positional — `None` marks an
/// index owned by another shard — so slices can be rejoined losslessly by
/// [`merge_shard_slices`].  With `jobs = 1` and no shard this is equivalent
/// to [`compare_schemes`] — same rows, same bytes.
pub fn compare_schemes_sharded(
    base: &ExperimentConfig,
    schemes: &[&str],
    opts: &ShardOpts,
) -> Result<Vec<Option<CompareRow>>> {
    sharder::run_sharded(schemes, opts, |rt, _idx, scheme| {
        compare_one(rt, base, scheme)
    })
}

pub fn print_compare_table(rows: &[CompareRow]) {
    crate::out!(
        "\n{:<13} {:>9} {:>9} {:>8} {:>8} {:>8} {:>10} {:>9} {:>6} {:>6} {:>8} {:>8}",
        "scheme", "final_acc", "best_acc", "w_bits", "a_bits", "g_bits",
        "converged", "hw_speed", "trips", "recov", "step_ms", "p95_ms"
    );
    crate::out!("{}", "-".repeat(114));
    for r in rows {
        crate::out!(
            "{:<13} {:>9.4} {:>9.4} {:>8.1} {:>8.1} {:>8.1} {:>10} {:>8.2}x {:>6} {:>6} {:>8.1} {:>8.1}",
            r.scheme, r.final_acc, r.best_acc, r.mean_w_bits, r.mean_a_bits,
            r.mean_g_bits, if r.converged { "yes" } else { "NO" }, r.hw_speedup,
            r.watchdog_trips, r.recoveries, r.mean_step_ms, r.p95_step_ms
        );
    }
    crate::out!();
}

/// The canonical JSON field list of one row — shared by the serial table
/// and the shard-slice format so a merged table re-emits byte-identically.
fn row_json_fields(r: &CompareRow) -> Vec<(&'static str, Json)> {
    vec![
        ("scheme", Json::Str(r.scheme.clone())),
        ("final_acc", Json::Num(r.final_acc as f64)),
        ("best_acc", Json::Num(r.best_acc as f64)),
        ("mean_w_bits", Json::Num(r.mean_w_bits)),
        ("mean_a_bits", Json::Num(r.mean_a_bits)),
        ("mean_g_bits", Json::Num(r.mean_g_bits)),
        ("converged", Json::Bool(r.converged)),
        ("hw_speedup", Json::Num(r.hw_speedup)),
        ("watchdog_trips", Json::Num(r.watchdog_trips as f64)),
        ("recoveries", Json::Num(r.recoveries as f64)),
        ("mean_step_ms", Json::Num(r.mean_step_ms)),
        ("p95_step_ms", Json::Num(r.p95_step_ms)),
    ]
}

pub fn compare_rows_json(rows: &[CompareRow]) -> Json {
    Json::Arr(rows.iter().map(|r| Json::obj(row_json_fields(r))).collect())
}

impl CompareRow {
    /// Parse one row back from its JSON form (shard-slice merging).
    pub fn from_json(j: &Json) -> Result<CompareRow> {
        let f = |k: &str| -> Result<f64> {
            j.get(k).as_f64().with_context(|| format!("row field '{k}'"))
        };
        Ok(CompareRow {
            scheme: j.get("scheme").as_str().context("row field 'scheme'")?.to_string(),
            final_acc: f("final_acc")? as f32,
            best_acc: f("best_acc")? as f32,
            mean_w_bits: f("mean_w_bits")?,
            mean_a_bits: f("mean_a_bits")?,
            mean_g_bits: f("mean_g_bits")?,
            converged: j.get("converged").as_bool().context("row field 'converged'")?,
            hw_speedup: f("hw_speedup")?,
            watchdog_trips: f("watchdog_trips")? as u64,
            recoveries: f("recoveries")? as u64,
            // absent in pre-telemetry shard slices: default rather than fail
            mean_step_ms: j.get("mean_step_ms").as_f64().unwrap_or(0.0),
            p95_step_ms: j.get("p95_step_ms").as_f64().unwrap_or(0.0),
        })
    }
}

/// One parsed `compare.shard-i-of-n.json` slice: which shard it is, how
/// many shards the sweep was split into, how many rows the *full* sweep
/// has, and this shard's rows tagged with their sweep index.
#[derive(Debug, Clone)]
pub struct ShardSlice {
    /// 1-based shard id (matches the `--shard i/n` syntax and filename).
    pub shard: usize,
    pub of: usize,
    /// Total rows across all shards (the sweep's scheme count).
    pub total: usize,
    pub rows: Vec<(usize, CompareRow)>,
}

/// Serialize one shard's positional results as a mergeable slice: rows
/// carry their sweep `index`, the envelope carries `shard`/`of`/`total`.
pub fn compare_shard_json(rows: &[Option<CompareRow>], shard: &Shard) -> Json {
    let tagged: Vec<Json> = rows
        .iter()
        .enumerate()
        .filter_map(|(idx, r)| r.as_ref().map(|r| (idx, r)))
        .map(|(idx, r)| {
            let mut fields = row_json_fields(r);
            fields.push(("index", Json::Num(idx as f64)));
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("shard", Json::Num((shard.index + 1) as f64)),
        ("of", Json::Num(shard.of as f64)),
        ("total", Json::Num(rows.len() as f64)),
        ("rows", Json::Arr(tagged)),
    ])
}

/// Parse one shard-slice file's text.
pub fn parse_shard_slice(text: &str) -> Result<ShardSlice> {
    let j = Json::parse(text).context("shard slice json")?;
    let shard = j.get("shard").as_usize().context("slice field 'shard'")?;
    let of = j.get("of").as_usize().context("slice field 'of'")?;
    let total = j.get("total").as_usize().context("slice field 'total'")?;
    anyhow::ensure!(of >= 1, "shard count must be >= 1");
    anyhow::ensure!(
        (1..=of).contains(&shard),
        "shard id {shard} out of range 1..={of}"
    );
    let rows = j
        .get("rows")
        .as_arr()
        .context("slice field 'rows'")?
        .iter()
        .map(|r| -> Result<(usize, CompareRow)> {
            let idx = r.get("index").as_usize().context("row field 'index'")?;
            anyhow::ensure!(idx < total, "row index {idx} out of range 0..{total}");
            Ok((idx, CompareRow::from_json(r)?))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(ShardSlice { shard, of, total, rows })
}

/// Join `compare.shard-i-of-n.json` slices back into one full table, in
/// sweep order.  Errors — instead of silently concatenating — when the
/// slices disagree on `of`/`total`, when a shard id appears twice, when a
/// shard is missing, or when row indices overlap or leave gaps.
pub fn merge_shard_slices(slices: &[ShardSlice]) -> Result<Vec<CompareRow>> {
    anyhow::ensure!(!slices.is_empty(), "merge needs at least one shard file");
    let (of, total) = (slices[0].of, slices[0].total);
    let mut seen_shards = vec![false; of];
    for s in slices {
        anyhow::ensure!(
            s.of == of && s.total == total,
            "shard {} is from a different sweep ({}-way/{} rows, expected {}-way/{} rows)",
            s.shard,
            s.of,
            s.total,
            of,
            total
        );
        anyhow::ensure!(
            !seen_shards[s.shard - 1],
            "shard {}/{of} supplied more than once",
            s.shard
        );
        seen_shards[s.shard - 1] = true;
    }
    if let Some(missing) = seen_shards.iter().position(|&ok| !ok) {
        anyhow::bail!("missing shard {}/{of}", missing + 1);
    }
    let mut merged: Vec<Option<CompareRow>> = (0..total).map(|_| None).collect();
    for s in slices {
        for (idx, row) in &s.rows {
            anyhow::ensure!(
                merged[*idx].is_none(),
                "row index {idx} ('{}') appears in more than one shard",
                row.scheme
            );
            merged[*idx] = Some(row.clone());
        }
    }
    merged
        .into_iter()
        .enumerate()
        .map(|(idx, r)| r.with_context(|| format!("no shard produced row index {idx}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(scheme: &str, acc: f32) -> CompareRow {
        CompareRow {
            scheme: scheme.to_string(),
            final_acc: acc,
            best_acc: acc + 0.01,
            mean_w_bits: 14.5,
            mean_a_bits: 12.25,
            mean_g_bits: 28.0,
            converged: true,
            hw_speedup: 1.75,
            watchdog_trips: 1,
            recoveries: 0,
            mean_step_ms: 12.5,
            p95_step_ms: 20.0,
        }
    }

    fn split(rows: &[CompareRow], of: usize) -> Vec<ShardSlice> {
        (1..=of)
            .map(|i| {
                let shard = Shard { index: i - 1, of };
                let slice: Vec<Option<CompareRow>> = rows
                    .iter()
                    .enumerate()
                    .map(|(idx, r)| shard.selects(idx).then(|| r.clone()))
                    .collect();
                parse_shard_slice(&compare_shard_json(&slice, &shard).to_string()).unwrap()
            })
            .collect()
    }

    #[test]
    fn row_json_roundtrip() {
        let r = row("qedps", 0.97);
        let back = CompareRow::from_json(&Json::obj(row_json_fields(&r))).unwrap();
        assert_eq!(
            Json::obj(row_json_fields(&r)).to_string(),
            Json::obj(row_json_fields(&back)).to_string()
        );
    }

    #[test]
    fn from_json_defaults_missing_timing_fields() {
        // pre-telemetry shard slices carry no wall-clock columns
        let r = row("qedps", 0.9);
        let mut fields = row_json_fields(&r);
        fields.retain(|(k, _)| *k != "mean_step_ms" && *k != "p95_step_ms");
        let back = CompareRow::from_json(&Json::obj(fields)).unwrap();
        assert_eq!(back.mean_step_ms, 0.0);
        assert_eq!(back.p95_step_ms, 0.0);
        assert_eq!(back.scheme, "qedps");
    }

    #[test]
    fn merge_rejoins_slices_byte_identically() {
        let rows: Vec<CompareRow> =
            ["qedps", "float", "fixed13", "na", "cn14"]
                .iter()
                .enumerate()
                .map(|(i, s)| row(s, 0.9 + i as f32 * 0.01))
                .collect();
        for of in 1..=3 {
            let merged = merge_shard_slices(&split(&rows, of)).unwrap();
            assert_eq!(
                compare_rows_json(&merged).to_string_pretty(),
                compare_rows_json(&rows).to_string_pretty(),
                "{of}-way split must merge back byte-identically"
            );
        }
        // merge order must not matter
        let mut slices = split(&rows, 3);
        slices.reverse();
        let merged = merge_shard_slices(&slices).unwrap();
        assert_eq!(
            compare_rows_json(&merged).to_string(),
            compare_rows_json(&rows).to_string()
        );
    }

    #[test]
    fn merge_rejects_missing_shard() {
        let rows: Vec<CompareRow> = ["a", "b", "c"].iter().map(|s| row(s, 0.9)).collect();
        let mut slices = split(&rows, 3);
        slices.remove(1);
        let err = merge_shard_slices(&slices).unwrap_err().to_string();
        assert!(err.contains("missing shard 2/3"), "{err}");
    }

    #[test]
    fn merge_rejects_duplicate_shard() {
        let rows: Vec<CompareRow> = ["a", "b"].iter().map(|s| row(s, 0.9)).collect();
        let mut slices = split(&rows, 2);
        slices.push(slices[0].clone());
        let err = merge_shard_slices(&slices).unwrap_err().to_string();
        assert!(err.contains("more than once"), "{err}");
    }

    #[test]
    fn merge_rejects_overlapping_rows() {
        let rows: Vec<CompareRow> = ["a", "b"].iter().map(|s| row(s, 0.9)).collect();
        let mut slices = split(&rows, 2);
        // shard 2 claims index 0 as well — overlap, not a valid partition
        slices[1].rows.push((0, row("a", 0.9)));
        let err = merge_shard_slices(&slices).unwrap_err().to_string();
        assert!(err.contains("more than one shard"), "{err}");
    }

    #[test]
    fn merge_rejects_mismatched_sweeps() {
        let rows2: Vec<CompareRow> = ["a", "b"].iter().map(|s| row(s, 0.9)).collect();
        let rows3: Vec<CompareRow> = ["a", "b", "c"].iter().map(|s| row(s, 0.9)).collect();
        let mut slices = split(&rows2, 2);
        slices[1] = split(&rows3, 2).remove(1);
        let err = merge_shard_slices(&slices).unwrap_err().to_string();
        assert!(err.contains("different sweep"), "{err}");
    }

    #[test]
    fn merge_rejects_gap() {
        let rows: Vec<CompareRow> = ["a", "b", "c"].iter().map(|s| row(s, 0.9)).collect();
        let mut slices = split(&rows, 3);
        slices[0].rows.clear(); // shard present but its row vanished
        let err = merge_shard_slices(&slices).unwrap_err().to_string();
        assert!(err.contains("no shard produced row index 0"), "{err}");
    }

    #[test]
    fn slice_parse_validates_envelope() {
        assert!(parse_shard_slice("{}").is_err());
        assert!(
            parse_shard_slice(r#"{"shard": 3, "of": 2, "total": 1, "rows": []}"#).is_err(),
            "shard id beyond count"
        );
        assert!(
            parse_shard_slice(r#"{"shard": 0, "of": 2, "total": 1, "rows": []}"#).is_err(),
            "shard id is 1-based"
        );
    }
}
