//! Deterministic fault injection: exercise the recovery path on purpose.
//!
//! Faults are declared as compact spec strings (CLI `--fault`, config
//! `faults.inject = [..]`) and applied by the training driver at exact
//! iterations, seeded through the repo's own [`Pcg32`] so every injected
//! corruption is reproducible:
//!
//! | spec                         | effect                                        |
//! |------------------------------|-----------------------------------------------|
//! | `nan@ITER`                   | force the observed loss to NaN at `ITER`      |
//! | `inf@ITER`                   | force the observed loss to +Inf at `ITER`     |
//! | `bitflip@ITER[:weight\|grad]`| flip one exponent bit in a stored tensor      |
//! | `read-fail[:N]`              | fail the next `N` guarded reads (default 1)   |
//!
//! `bitflip` targets host-resident state: `weight` flips a parameter
//! tensor, `grad` flips a momentum tensor (activations are
//! device-transient and cannot be corrupted from L3; asking for
//! `bitflip@N:act` is a spec error).  Scheduled faults are **one-shot**:
//! after a rollback re-executes the same iteration the fault does not fire
//! again, so a bounded retry budget always converges.

use anyhow::{bail, Context, Result};

use crate::policy::Class;
use crate::util::rng::Pcg32;

/// One scheduled fault (parsed from a spec string).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    NanLoss { at: u64 },
    InfLoss { at: u64 },
    BitFlip { at: u64, class: Class },
    ReadFail { count: u32 },
}

/// Parse one spec string (see module docs for the grammar).
pub fn parse_spec(spec: &str) -> Result<Fault> {
    let (head, tail) = match spec.split_once('@') {
        Some((h, t)) => (h, Some(t)),
        None => (spec, None),
    };
    match head {
        "nan" => {
            let at = parse_iter(spec, tail)?;
            Ok(Fault::NanLoss { at })
        }
        "inf" => {
            let at = parse_iter(spec, tail)?;
            Ok(Fault::InfLoss { at })
        }
        "bitflip" => {
            let tail = tail.with_context(|| format!("'{spec}': bitflip needs @ITER"))?;
            let (it, class) = match tail.split_once(':') {
                Some((it, "weight")) => (it, Class::Weight),
                Some((it, "grad")) => (it, Class::Grad),
                Some((_, "act")) => bail!(
                    "'{spec}': activations are device-transient; flip 'weight' or 'grad'"
                ),
                Some((_, other)) => bail!("'{spec}': unknown class '{other}'"),
                None => (tail, Class::Weight),
            };
            let at = it.parse().with_context(|| format!("'{spec}': bad iteration"))?;
            Ok(Fault::BitFlip { at, class })
        }
        _ if head.starts_with("read-fail") => {
            let count = match head.strip_prefix("read-fail") {
                Some("") => 1,
                Some(rest) => rest
                    .strip_prefix(':')
                    .and_then(|n| n.parse().ok())
                    .with_context(|| format!("'{spec}': read-fail[:N]"))?,
                None => unreachable!(),
            };
            Ok(Fault::ReadFail { count })
        }
        other => bail!(
            "unknown fault '{other}' in '{spec}' \
             (nan@N | inf@N | bitflip@N[:weight|grad] | read-fail[:N])"
        ),
    }
}

fn parse_iter(spec: &str, tail: Option<&str>) -> Result<u64> {
    tail.with_context(|| format!("'{spec}': needs @ITER"))?
        .parse()
        .with_context(|| format!("'{spec}': bad iteration"))
}

/// Holds the fault plan plus the seeded RNG that picks corruption sites.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rng: Pcg32,
    faults: Vec<Fault>,
    /// Remaining guarded reads to fail (sum of `ReadFail` counts).
    read_fails: u32,
}

impl FaultInjector {
    /// The RNG stream id keeps fault-site choices independent of every
    /// other consumer of the seed.
    const STREAM: u64 = 0xFA_017;

    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg32::new(seed, Self::STREAM), faults: Vec::new(), read_fails: 0 }
    }

    pub fn from_specs(specs: &[String], seed: u64) -> Result<Self> {
        let mut inj = Self::new(seed);
        for s in specs {
            match parse_spec(s)? {
                Fault::ReadFail { count } => inj.read_fails += count,
                f => inj.faults.push(f),
            }
        }
        Ok(inj)
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty() && self.read_fails == 0
    }

    /// Forced loss for this iteration, if a NaN/Inf fault is due (one-shot).
    pub fn loss_override(&mut self, iter: u64) -> Option<f32> {
        let pos = self.faults.iter().position(|f| {
            matches!(f, Fault::NanLoss { at } | Fault::InfLoss { at } if *at == iter)
        })?;
        crate::telemetry::count("faults.injected", 1);
        match self.faults.remove(pos) {
            Fault::NanLoss { .. } => Some(f32::NAN),
            Fault::InfLoss { .. } => Some(f32::INFINITY),
            _ => unreachable!(),
        }
    }

    /// Class whose stored tensor gets one bit flipped before this
    /// iteration, if a bit-flip fault is due (one-shot).
    pub fn bitflip(&mut self, iter: u64) -> Option<Class> {
        let pos = self
            .faults
            .iter()
            .position(|f| matches!(f, Fault::BitFlip { at, .. } if *at == iter))?;
        crate::telemetry::count("faults.injected", 1);
        match self.faults.remove(pos) {
            Fault::BitFlip { class, .. } => Some(class),
            _ => unreachable!(),
        }
    }

    /// Simulated transient failure for a guarded read; `Some(err)` while
    /// injected failures remain.
    pub fn take_read_failure(&mut self, what: &str) -> Option<anyhow::Error> {
        if self.read_fails == 0 {
            return None;
        }
        self.read_fails -= 1;
        crate::telemetry::count("faults.injected", 1);
        Some(anyhow::anyhow!("injected transient read failure ({what})"))
    }

    /// Deterministically choose a (tensor, element, exponent-bit) corruption
    /// site.  `elems(t)` reports tensor `t`'s element count.  The bit is
    /// drawn from the f32 exponent field (bits 23..=30) so the flip always
    /// lands far outside the representable fixed-point range.
    pub fn flip_site(
        &mut self,
        n_tensors: usize,
        elems: impl Fn(usize) -> usize,
    ) -> (usize, usize, u32) {
        let t = self.rng.below(n_tensors.max(1) as u32) as usize;
        let i = self.rng.below(elems(t).max(1) as u32) as usize;
        let bit = 23 + self.rng.below(8);
        (t, i, bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_spec_form() {
        assert_eq!(parse_spec("nan@12").unwrap(), Fault::NanLoss { at: 12 });
        assert_eq!(parse_spec("inf@0").unwrap(), Fault::InfLoss { at: 0 });
        assert_eq!(
            parse_spec("bitflip@7").unwrap(),
            Fault::BitFlip { at: 7, class: Class::Weight }
        );
        assert_eq!(
            parse_spec("bitflip@7:grad").unwrap(),
            Fault::BitFlip { at: 7, class: Class::Grad }
        );
        assert_eq!(parse_spec("read-fail").unwrap(), Fault::ReadFail { count: 1 });
        assert_eq!(parse_spec("read-fail:3").unwrap(), Fault::ReadFail { count: 3 });
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in ["nan", "nan@x", "bitflip", "bitflip@3:act", "bitflip@3:nope",
                    "warp@9", "read-fail:x"] {
            assert!(parse_spec(bad).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn faults_are_one_shot() {
        let specs = vec!["nan@5".to_string(), "bitflip@3:weight".to_string()];
        let mut inj = FaultInjector::from_specs(&specs, 1).unwrap();
        assert_eq!(inj.bitflip(2), None);
        assert_eq!(inj.bitflip(3), Some(Class::Weight));
        assert_eq!(inj.bitflip(3), None, "bitflip must not re-fire on replay");
        assert!(inj.loss_override(5).unwrap().is_nan());
        assert_eq!(inj.loss_override(5), None, "nan must not re-fire on replay");
        assert!(inj.is_empty());
    }

    #[test]
    fn inf_override_is_infinite() {
        let mut inj = FaultInjector::from_specs(&["inf@1".to_string()], 1).unwrap();
        assert_eq!(inj.loss_override(1), Some(f32::INFINITY));
    }

    #[test]
    fn read_failures_count_down() {
        let mut inj = FaultInjector::from_specs(&["read-fail:2".to_string()], 1).unwrap();
        assert!(inj.take_read_failure("x").is_some());
        assert!(inj.take_read_failure("x").is_some());
        assert!(inj.take_read_failure("x").is_none());
    }

    #[test]
    fn flip_sites_are_deterministic_and_in_range() {
        let sizes = [100usize, 7, 3000];
        let mut a = FaultInjector::new(42);
        let mut b = FaultInjector::new(42);
        for _ in 0..50 {
            let sa = a.flip_site(sizes.len(), |t| sizes[t]);
            let sb = b.flip_site(sizes.len(), |t| sizes[t]);
            assert_eq!(sa, sb);
            let (t, i, bit) = sa;
            assert!(t < sizes.len());
            assert!(i < sizes[t]);
            assert!((23..=30).contains(&bit));
        }
    }
}
