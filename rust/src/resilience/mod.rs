//! Training-run resilience: divergence watchdog, rollback-with-escalation,
//! deterministic fault injection, and structured failure reporting.
//!
//! Aggressive low-precision training is *designed* to run at the edge of
//! divergence: Gupta et al. (2015) show fixed-point runs collapse outright
//! when the format is too narrow, and the paper's own controller probes
//! bit-width downward every iteration.  This module makes a run survive
//! crossing that edge — and survive the mundane failures (torn checkpoint
//! writes, flaky artifact reads, corrupt data files) that kill long runs in
//! practice:
//!
//! * [`watchdog`] — detects divergence from the per-iteration feedback
//!   (non-finite loss, loss explosion vs a running baseline, sustained
//!   overflow rate);
//! * [`faults`] — seeded, spec-driven fault injection (bit-flips in stored
//!   tensors, forced NaN/Inf losses, simulated transient read failures) so
//!   the recovery path is exercisable deterministically in tests and
//!   `examples/fault_recovery.rs`;
//! * [`retry`] — retry-with-backoff used by the runtime loader and the
//!   data pipeline for transient IO;
//! * [`FailureReport`] — the machine-readable post-mortem written when the
//!   retry budget is exhausted and the run aborts gracefully.
//!
//! The *response* side — rollback to the last complete checkpoint plus
//! precision escalation through [`crate::policy::Policy::escalate`], with a
//! bounded retry budget and exponential backoff — lives in
//! [`crate::trainer::run_experiment`]; crash-safe checkpoint IO lives in
//! [`crate::trainer::checkpoint`].

pub mod faults;
pub mod retry;
pub mod watchdog;

pub use faults::{parse_spec, Fault, FaultInjector};
pub use retry::retry_with_backoff;
pub use watchdog::{TripReason, Watchdog, WatchdogConfig};

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::metrics::History;
use crate::util::json::Json;

/// Written to `<out_dir>/failure_report.json` when a run exhausts its
/// recovery budget: everything needed to triage the abort offline.
#[derive(Debug, Clone)]
pub struct FailureReport {
    pub scheme: String,
    pub model: String,
    /// Iteration of the final, fatal trip.
    pub iter: u64,
    /// Recovery attempts consumed before aborting.
    pub attempts: u64,
    /// Human-readable reason of the final trip.
    pub reason: String,
}

impl FailureReport {
    /// Serialize the report plus the run's recovery-event trail.
    pub fn to_json(&self, hist: &History) -> Json {
        Json::obj(vec![
            ("status", Json::Str("aborted".into())),
            ("scheme", Json::Str(self.scheme.clone())),
            ("model", Json::Str(self.model.clone())),
            ("iter", Json::Num(self.iter as f64)),
            ("attempts", Json::Num(self.attempts as f64)),
            ("reason", Json::Str(self.reason.clone())),
            ("recovery_events", hist.recovery_json()),
        ])
    }

    /// Write the report under `dir` and return its path.
    pub fn write(&self, dir: &str, hist: &History) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = Path::new(dir).join("failure_report.json");
        std::fs::write(&path, self.to_json(hist).to_string_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_report_roundtrips_through_json() {
        let mut hist = History::new("qedps", "mlp");
        hist.recovery.push(crate::metrics::RecoveryEvent {
            iter: 12,
            kind: "non_finite_loss".into(),
            detail: "loss is not finite (NaN)".into(),
            rollback_to: Some(10),
        });
        let report = FailureReport {
            scheme: "qedps".into(),
            model: "mlp".into(),
            iter: 15,
            attempts: 3,
            reason: "loss is not finite (NaN)".into(),
        };
        let dir = std::env::temp_dir().join("qedps_failure_report");
        std::fs::create_dir_all(&dir).unwrap();
        let path = report.write(&dir.to_string_lossy(), &hist).unwrap();
        let j = Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(j.get("status").as_str(), Some("aborted"));
        assert_eq!(j.get("attempts").as_f64(), Some(3.0));
        assert_eq!(
            j.get("recovery_events").at(0).get("kind").as_str(),
            Some("non_finite_loss")
        );
        assert_eq!(
            j.get("recovery_events").at(0).get("rollback_to").as_f64(),
            Some(10.0)
        );
    }
}
