//! The divergence watchdog: decides *when* a run is in trouble.
//!
//! Low-precision training sits on the edge of divergence by design — the
//! whole point of the paper's controller is to probe bit-width downward
//! until the quantization signals push back.  When the probe goes too far
//! (or a hardware fault corrupts state), three symptoms show up in the
//! per-iteration feedback the trainer already collects:
//!
//! 1. **non-finite loss** — NaN/Inf from overflowed accumulators;
//! 2. **loss explosion** — loss far above its recent running baseline;
//! 3. **sustained overflow** — a class's overflow rate `R` pinned high for
//!    many consecutive iterations (clipping is corrupting dot products
//!    faster than the radix controller can react).
//!
//! The watchdog is purely observational: it consumes [`Feedback`] and
//! returns a [`TripReason`]; the rollback/escalation response lives in the
//! trainer driver.  After a rollback the driver calls [`Watchdog::hold_until`]
//! to grant an exponentially growing grace window so escalation has room to
//! take effect before the next trip can fire.

use crate::policy::{Class, Feedback};

/// Watchdog thresholds (see [`crate::config::ExperimentConfig`] for the
/// TOML/CLI surface; these defaults match `ExperimentConfig::default`).
#[derive(Debug, Clone, Copy)]
pub struct WatchdogConfig {
    /// Trip when a finite loss exceeds `loss_ratio * baseline` (EWMA).
    pub loss_ratio: f32,
    /// Number of finite-loss observations before the ratio rule arms.
    pub warmup: u64,
    /// Per-class overflow rate considered "saturating".
    pub r_trip: f32,
    /// Consecutive iterations above `r_trip` before tripping.
    pub r_window: u64,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        Self { loss_ratio: 4.0, warmup: 20, r_trip: 0.25, r_window: 8 }
    }
}

/// Why the watchdog tripped.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TripReason {
    NonFiniteLoss { loss: f32 },
    LossExplosion { loss: f32, baseline: f32 },
    SustainedOverflow { class: Class, r: f32, window: u64 },
}

impl TripReason {
    /// Stable string tag recorded into metrics / failure reports.
    pub fn kind(&self) -> &'static str {
        match self {
            TripReason::NonFiniteLoss { .. } => "non_finite_loss",
            TripReason::LossExplosion { .. } => "loss_explosion",
            TripReason::SustainedOverflow { .. } => "sustained_overflow",
        }
    }

    /// The attribute class to escalate, when the symptom names one.
    pub fn class(&self) -> Option<Class> {
        match self {
            TripReason::SustainedOverflow { class, .. } => Some(*class),
            _ => None,
        }
    }
}

impl std::fmt::Display for TripReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TripReason::NonFiniteLoss { loss } => write!(f, "loss is not finite ({loss})"),
            TripReason::LossExplosion { loss, baseline } => {
                write!(f, "loss exploded ({loss:.4} vs baseline {baseline:.4})")
            }
            TripReason::SustainedOverflow { class, r, window } => {
                write!(f, "overflow rate pinned at {r:.3} for {window} iters ({class:?})")
            }
        }
    }
}

const CLASSES: [Class; 3] = [Class::Weight, Class::Act, Class::Grad];

/// Streaming divergence detector; one instance per training attempt.
#[derive(Debug, Clone)]
pub struct Watchdog {
    cfg: WatchdogConfig,
    /// EWMA of finite losses (the explosion baseline).
    ewma: f64,
    /// Finite-loss observations folded into the EWMA so far.
    seen: u64,
    /// Consecutive iterations with `R > r_trip`, per class.
    over: [u64; 3],
    /// Trips are suppressed while `iter < armed_at` (post-rollback grace).
    armed_at: u64,
}

impl Watchdog {
    const ALPHA: f64 = 0.1;

    pub fn new(cfg: WatchdogConfig) -> Self {
        Self { cfg, ewma: 0.0, seen: 0, over: [0; 3], armed_at: 0 }
    }

    /// Every trip exits through here so the `watchdog.trips` counter stays
    /// in lock-step with what [`Watchdog::observe`] reports.
    fn tripped(reason: TripReason) -> TripReason {
        crate::telemetry::count("watchdog.trips", 1);
        reason
    }

    /// Feed one iteration's feedback; `Some(reason)` means roll back now.
    pub fn observe(&mut self, fb: &Feedback) -> Option<TripReason> {
        let armed = fb.iter >= self.armed_at;
        for (i, class) in CLASSES.into_iter().enumerate() {
            if fb.class(class).r > self.cfg.r_trip {
                self.over[i] += 1;
            } else {
                self.over[i] = 0;
            }
        }

        if !fb.loss.is_finite() {
            return armed
                .then_some(TripReason::NonFiniteLoss { loss: fb.loss })
                .map(Self::tripped);
        }

        // Compare against the baseline *before* folding the new loss in, so
        // a fast blow-up cannot drag its own baseline upward.
        let baseline = (self.ewma) as f32;
        if armed
            && self.seen >= self.cfg.warmup
            && fb.loss > self.cfg.loss_ratio * baseline
        {
            return Some(Self::tripped(TripReason::LossExplosion { loss: fb.loss, baseline }));
        }
        self.ewma = if self.seen == 0 {
            fb.loss as f64
        } else {
            (1.0 - Self::ALPHA) * self.ewma + Self::ALPHA * fb.loss as f64
        };
        self.seen += 1;

        if armed {
            for (i, class) in CLASSES.into_iter().enumerate() {
                if self.over[i] >= self.cfg.r_window {
                    self.over[i] = 0;
                    return Some(Self::tripped(TripReason::SustainedOverflow {
                        class,
                        r: fb.class(class).r,
                        window: self.cfg.r_window,
                    }));
                }
            }
        }
        None
    }

    /// Suppress trips until `iter` (exponential-backoff grace after a
    /// rollback) and clear the overflow streaks.
    pub fn hold_until(&mut self, iter: u64) {
        self.armed_at = iter;
        self.over = [0; 3];
    }

    /// Forget the loss baseline (the run state was rewound past it).
    pub fn reset_baseline(&mut self) {
        self.ewma = 0.0;
        self.seen = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ClassStats;

    fn fb(iter: u64, loss: f32, r: f32) -> Feedback {
        let s = ClassStats { e: 0.0, r };
        Feedback { iter, loss, weights: s, acts: s, grads: s }
    }

    #[test]
    fn trips_on_non_finite_loss() {
        let mut w = Watchdog::new(WatchdogConfig::default());
        assert_eq!(w.observe(&fb(0, 1.0, 0.0)), None);
        let trip = w.observe(&fb(1, f32::NAN, 0.0)).expect("must trip");
        assert_eq!(trip.kind(), "non_finite_loss");
        assert_eq!(trip.class(), None);
    }

    #[test]
    fn trips_on_loss_explosion_after_warmup() {
        let cfg = WatchdogConfig { warmup: 5, ..Default::default() };
        let mut w = Watchdog::new(cfg);
        for i in 0..10 {
            assert_eq!(w.observe(&fb(i, 1.0, 0.0)), None, "iter {i}");
        }
        // 10x the baseline with ratio 4: trip
        let trip = w.observe(&fb(10, 10.0, 0.0)).expect("must trip");
        assert_eq!(trip.kind(), "loss_explosion");
    }

    #[test]
    fn no_explosion_trip_during_warmup() {
        let cfg = WatchdogConfig { warmup: 50, ..Default::default() };
        let mut w = Watchdog::new(cfg);
        assert_eq!(w.observe(&fb(0, 1.0, 0.0)), None);
        assert_eq!(w.observe(&fb(1, 100.0, 0.0)), None);
    }

    #[test]
    fn trips_on_sustained_overflow_with_class() {
        let cfg = WatchdogConfig { r_trip: 0.2, r_window: 3, ..Default::default() };
        let mut w = Watchdog::new(cfg);
        assert_eq!(w.observe(&fb(0, 1.0, 0.5)), None);
        assert_eq!(w.observe(&fb(1, 1.0, 0.5)), None);
        let trip = w.observe(&fb(2, 1.0, 0.5)).expect("must trip");
        assert_eq!(trip.kind(), "sustained_overflow");
        // Weight is checked first
        assert_eq!(trip.class(), Some(Class::Weight));
    }

    #[test]
    fn overflow_streak_resets_on_clean_iteration() {
        let cfg = WatchdogConfig { r_trip: 0.2, r_window: 3, ..Default::default() };
        let mut w = Watchdog::new(cfg);
        for i in 0..10 {
            // alternating dirty/clean never accumulates a window
            let r = if i % 2 == 0 { 0.5 } else { 0.0 };
            assert_eq!(w.observe(&fb(i, 1.0, r)), None, "iter {i}");
        }
    }

    #[test]
    fn hold_until_grants_grace() {
        let mut w = Watchdog::new(WatchdogConfig::default());
        w.hold_until(100);
        assert_eq!(w.observe(&fb(50, f32::NAN, 0.0)), None);
        assert!(w.observe(&fb(100, f32::NAN, 0.0)).is_some());
    }

    #[test]
    fn reset_baseline_forgets_history() {
        let cfg = WatchdogConfig { warmup: 2, ..Default::default() };
        let mut w = Watchdog::new(cfg);
        for i in 0..5 {
            w.observe(&fb(i, 0.1, 0.0));
        }
        w.reset_baseline();
        // would have tripped against the 0.1 baseline; fresh baseline absorbs it
        assert_eq!(w.observe(&fb(5, 5.0, 0.0)), None);
    }
}
