//! Retry-with-backoff for transient IO: artifact parses, param npz reads,
//! dataset loads.  A network filesystem hiccup or an injected
//! [`crate::resilience::FaultInjector`] read failure should cost a warning
//! and a short sleep, not the whole run.

use anyhow::{Context, Result};

/// Run `op` up to `attempts` times, sleeping `base_delay_ms * 2^k` between
/// failures.  `op` receives the 0-based attempt index (so callers can
/// consult a fault injector on early attempts only, log differently, etc.).
/// The final error carries the attempt count as context.
pub fn retry_with_backoff<T>(
    what: &str,
    attempts: u32,
    base_delay_ms: u64,
    mut op: impl FnMut(u32) -> Result<T>,
) -> Result<T> {
    let attempts = attempts.max(1);
    let mut delay = base_delay_ms;
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..attempts {
        match op(attempt) {
            Ok(v) => {
                if attempt > 0 {
                    crate::log_info!("{what}: recovered on attempt {}", attempt + 1);
                }
                return Ok(v);
            }
            Err(e) => {
                // failed attempts only: a clean run leaves the counter at 0
                crate::telemetry::count("retry.attempts", 1);
                if attempt + 1 < attempts {
                    crate::log_warn!(
                        "{what}: attempt {}/{attempts} failed ({e:#}); retrying in {delay}ms",
                        attempt + 1
                    );
                    std::thread::sleep(std::time::Duration::from_millis(delay));
                    delay = delay.saturating_mul(2);
                }
                last = Some(e);
            }
        }
    }
    Err(last
        .unwrap_or_else(|| anyhow::anyhow!("no attempts made"))
        .context(format!("{what}: failed after {attempts} attempts")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn succeeds_first_try() {
        let mut calls = 0;
        let v = retry_with_backoff("t", 3, 0, |_| {
            calls += 1;
            Ok(7)
        })
        .unwrap();
        assert_eq!(v, 7);
        assert_eq!(calls, 1);
    }

    #[test]
    fn recovers_after_transient_failures() {
        let mut calls = 0;
        let v: i32 = retry_with_backoff("t", 4, 0, |attempt| {
            calls += 1;
            if attempt < 2 {
                anyhow::bail!("transient");
            }
            Ok(9)
        })
        .unwrap();
        assert_eq!(v, 9);
        assert_eq!(calls, 3);
    }

    #[test]
    fn exhaustion_reports_attempts() {
        let err = retry_with_backoff::<()>("flaky-read", 3, 0, |_| anyhow::bail!("nope"))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("after 3 attempts"), "{msg}");
        assert!(msg.contains("nope"), "{msg}");
    }

    #[test]
    fn zero_attempts_clamped_to_one() {
        let mut calls = 0;
        let _ = retry_with_backoff::<()>("t", 0, 0, |_| {
            calls += 1;
            anyhow::bail!("x")
        });
        assert_eq!(calls, 1);
    }
}
