#!/usr/bin/env bash
# Tier-1 verification: build, test, lint, and a fault-recovery smoke run.
#
# Usage: scripts/tier1.sh [--no-smoke]
#
# The smoke step needs the AOT artifacts (`make artifacts`); pass
# --no-smoke (or run without artifacts present — it is skipped with a
# notice) on machines that only have the Rust toolchain.

set -euo pipefail
cd "$(dirname "$0")/.."

NO_SMOKE=0
for arg in "$@"; do
    case "$arg" in
        --no-smoke) NO_SMOKE=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "== tier1: print discipline (library stdout goes through crate::out!) =="
# Library code must not print directly: stdout belongs to crate::out! (so
# product output stays greppable/redirectable) and diagnostics belong to
# the log_* macros.  The CLI entry points and the logger itself are the
# only legitimate direct printers.
VIOLATIONS=$(grep -rn --include='*.rs' -E '\b(println|eprintln)!' rust/src \
    | grep -v -E 'rust/src/(cli\.rs|main\.rs|util/logging\.rs)' || true)
if [ -n "$VIOLATIONS" ]; then
    echo "bare println!/eprintln! in library code (use crate::out! / log_* macros):" >&2
    echo "$VIOLATIONS" >&2
    exit 1
fi

echo "== tier1: cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "rustfmt not installed; skipping format check"
fi

echo "== tier1: cargo build --release =="
cargo build --release

echo "== tier1: cargo test =="
cargo test -q

echo "== tier1: clippy (deny warnings) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "clippy not installed; skipping lint"
fi

if [ "$NO_SMOKE" -eq 1 ]; then
    echo "== tier1: smoke skipped (--no-smoke) =="
elif [ -f artifacts/manifest.json ] || [ -n "${QEDPS_ARTIFACTS:-}" ]; then
    echo "== tier1: fault-recovery smoke =="
    cargo run --release --example fault_recovery
    echo "== tier1: step-loop invariants (literal builds + host transfers) =="
    # bench step exits nonzero if the timed loop constructs literals or, on
    # a device-resident run, copies state across host<->device
    cargo run --release -- bench step --iters 5 --quiet
    echo "== tier1: eval-pass invariants (cached eval set stays flat) =="
    # bench eval exits nonzero if steady-state eval passes construct
    # literals, upload inputs, or (device-resident) touch state/host
    # transfers; --json exercises the pinned report schema end to end
    cargo run --release -- bench eval --iters 3 --quiet --json target/tier1_bench_eval.json
else
    echo "== tier1: smoke skipped (no artifacts; run 'make artifacts') =="
fi

echo "== tier1: OK =="
